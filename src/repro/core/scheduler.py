"""Event-driven execution planning — the TPU analogue of AMPLE's NID/nodeslots.

AMPLE's Node Instruction Decoder lets the host program each node independently
into a nodeslot; slots are freed the moment a node finishes, so low-degree
nodes never wait behind high-degree stragglers (the double-buffering problem of
HyGCN). On an SPMD machine the equivalent is built *ahead of time*: this module
compiles a graph (or any skewed bag of variable-length segments — MoE token
routing reuses it) into dense, fixed-shape **edge tiles** whose total compute
is proportional to Σ degree(v), not n_batches × max_degree.

Three schedules are produced, mirroring the paper's comparison axis:

* ``EdgeTilePlan``   — the event-driven schedule (AMPLE). Edges are packed
  back-to-back into tiles of ``edges_per_tile`` lanes; a node whose degree
  exceeds the remaining lane budget of the current tile is *split across
  tiles* and its aggregate assembled by scatter-add — this is exactly the
  Feature Bank's partial-response mechanism (§3.3 of the paper).
* ``BucketPlan``     — degree-bucketed padding (power-of-two capacities);
  bounded ≤2× lane waste. Used for max-aggregation and as a mid point.
* ``PaddedPlan``     — the HyGCN-style double-buffer baseline: fixed batches
  padded to the per-batch max degree. Its ``pipeline_gap_ratio`` is the
  quantity AMPLE eliminates.

Mixed precision (§3.2): ``build_mixed_precision_plans`` partitions nodes by
their Degree-Quant tag and emits one plan per precision group — the analogue
of the isolated per-precision NoC sub-networks.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.graphs.csr import Graph

__all__ = [
    "EdgeTilePlan",
    "Bucket",
    "BucketPlan",
    "PaddedPlan",
    "ChunkSchedule",
    "build_edge_tile_plan",
    "build_bucket_plan",
    "build_padded_plan",
    "build_mixed_precision_plans",
    "build_chunk_schedule",
    "pack_tiles_by_chunk",
    "tile_runs",
    "split_plan_by_halo",
    "pack_segments",
    "concat_tile_plans",
    "graph_fingerprint",
    "plan_fingerprint",
    "partition_fingerprint",
    "shard_plan_fingerprint",
    "size_class",
    "union_bucket_fingerprint",
]


# ---------------------------------------------------------------------------
# Plan fingerprinting — the cache key of the serving layer
# ---------------------------------------------------------------------------


def graph_fingerprint(g: Graph) -> str:
    """Structure hash of a graph (topology only, not features).

    Two graphs with identical (num_nodes, indptr, indices) — hence identical
    CSR structure — hash identically, so a compiled ExecutionPlan for one is
    valid for the other. Edge weights and features are runtime inputs, not
    plan inputs, and are deliberately excluded.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(g.num_nodes).tobytes())
    h.update(np.ascontiguousarray(g.indptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(g.indices, dtype=np.int32).tobytes())
    return h.hexdigest()


def plan_fingerprint(g: Graph, *parts: str) -> str:
    """Fingerprint of (graph structure, planner configuration) pairs.

    ``parts`` are deterministic strings describing everything that shapes the
    compiled plan beyond topology: the EngineConfig repr, the coefficient
    modes, the arch. Same fingerprint ⇒ the planner would emit identical
    tiles, so the plan may be served from cache.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(graph_fingerprint(g).encode())
    for p in parts:
        h.update(b"\x00")
        h.update(str(p).encode())
    return h.hexdigest()


def partition_fingerprint(g: Graph, part) -> str:
    """Hash of (graph structure, shard assignment, partitioner identity).

    ``part`` is a ``graphs.partition.Partition`` — or, for backwards
    compatibility, a bare ``starts`` array (int64[num_shards + 1]), which
    hashes like a contiguous ``"edges"``-kind partition. The hash covers the
    block boundaries, the node permutation (when the assignment is
    non-contiguous), and the partitioner ``kind`` string — including its
    parameters — so plan caches can never serve a plan compiled under a
    different partitioner that happened to emit the same boundaries.
    """
    starts = getattr(part, "starts", part)
    order = getattr(part, "order", None)
    kind = str(getattr(part, "kind", "edges"))
    h = hashlib.blake2b(digest_size=16)
    h.update(graph_fingerprint(g).encode())
    h.update(b"\x00part:")
    h.update(np.ascontiguousarray(starts, dtype=np.int64).tobytes())
    h.update(b"\x00kind:")
    h.update(kind.encode())
    if order is not None:
        h.update(b"\x00order:")
        h.update(np.ascontiguousarray(order, dtype=np.int64).tobytes())
    return h.hexdigest()


def shard_plan_fingerprint(g: Graph, part, shard: int, *parts: str) -> str:
    """Fingerprint of one shard's compiled plan within a partitioned graph.

    Extends ``partition_fingerprint`` with the shard index and the planner
    configuration strings (EngineConfig repr, modes, arch …). This is the key
    the serving layer caches per-shard plans under: repeat traffic on the same
    (structure, partition) pair hits every shard independently.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(partition_fingerprint(g, part).encode())
    h.update(f"\x00shard:{int(shard)}".encode())
    for p in parts:
        h.update(b"\x00")
        h.update(str(p).encode())
    return h.hexdigest()


def size_class(
    num_nodes: int, num_edges: int, node_bucket: int, edge_bucket: int
) -> Tuple[int, int]:
    """Round a (nodes, edges) pair up to its padded size class.

    A bucket of 0 (or negative) leaves that dimension exact. Size classes are
    the continuous-batching analogue of AMPLE's fixed nodeslot count: padding
    a disjoint-union batch up to the class boundary trades a bounded amount
    of wasted lanes for device-call shapes that recur across different member
    mixes, so the jit cache and the plan cache both stop churning.
    """
    n = int(num_nodes)
    e = int(num_edges)
    if node_bucket > 0:
        n = max(((n + node_bucket - 1) // node_bucket) * node_bucket, node_bucket)
    if edge_bucket > 0:
        e = max(((e + edge_bucket - 1) // edge_bucket) * edge_bucket, edge_bucket)
    return n, e


def union_bucket_fingerprint(
    num_nodes: int,
    num_edges: int,
    node_bucket: int,
    edge_bucket: int,
    *parts: str,
) -> str:
    """Fingerprint of a padded union's **size class**, not its member mix.

    Two disjoint-union batches whose (nodes, edges) land in the same bucket —
    under the same planner configuration ``parts`` — hash identically, even
    when their member graphs differ. The serving layer keys its class-level
    cache on this, so warm size classes skip shape-dependent work (device
    uploads, jit traces) however the admission window recomposed the batch;
    the member-level plan pieces carry the structure-exact identity.

    Granularity caveat: the class is keyed on the **total** edge count, while
    mixed-precision plans pad tiles per precision group — two mixes in one
    class whose float/int8 edge split straddles a tile-bucket boundary still
    trace separately. A warm class is therefore an upper bound on shape
    reuse under ``mixed_precision``; it is exact under the float policy.
    """
    n, e = size_class(num_nodes, num_edges, node_bucket, edge_bucket)
    h = hashlib.blake2b(digest_size=16)
    h.update(f"class:{n}:{e}:{int(node_bucket)}:{int(edge_bucket)}".encode())
    for p in parts:
        h.update(b"\x00")
        h.update(str(p).encode())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Event-driven schedule: edge tiles (compute ∝ number of edges)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EdgeTilePlan:
    """Dense tile arrays consumed by the aggregation engine / Pallas kernel.

    Shapes: T = num_tiles, E = edges_per_tile, S = segments_per_tile.

      gather_idx: int32[T, E]  source node id per lane (0 where invalid).
      coeff:      f32[T, E]    per-edge weight; 0 on invalid lanes, so it acts
                               as both the aggregation coefficient (GCN norm,
                               1/deg for mean, 1 for sum) and the lane mask.
      seg_ids:    int32[T, E]  local segment (nodeslot) within the tile.
      out_node:   int32[T, S]  global node each local segment accumulates into;
                               sentinel ``num_nodes`` for unused segments.
      node_ids:   int32[M]     nodes covered by this plan (plan may cover a
                               precision subset of the graph).
      edge_ids:   int32[T, E]  graph edge index (CSR position) per lane; -1 on
                               padding lanes. The runtime-coefficient
                               indirection: a per-edge vector computed at
                               request time (GAT attention) is scattered into
                               tile layout through this map, so plans stay
                               structure-keyed while coefficients change every
                               request.
    """

    gather_idx: np.ndarray
    coeff: np.ndarray
    seg_ids: np.ndarray
    out_node: np.ndarray
    node_ids: np.ndarray
    edge_ids: np.ndarray
    num_nodes: int  # of the full graph (scatter target row count)
    edges_per_tile: int
    segments_per_tile: int
    total_edges: int  # real (unpadded) edges covered

    @property
    def num_tiles(self) -> int:
        return int(self.gather_idx.shape[0])

    @property
    def lane_occupancy(self) -> float:
        """Fraction of gather lanes carrying a real edge (1.0 = no gaps)."""
        lanes = self.gather_idx.size
        return float(self.total_edges) / float(lanes) if lanes else 1.0


def build_edge_tile_plan(
    g: Graph,
    *,
    edges_per_tile: int = 256,
    segments_per_tile: Optional[int] = None,
    coeff: Optional[np.ndarray] = None,
    node_ids: Optional[np.ndarray] = None,
    sort_by_degree: bool = True,
) -> EdgeTilePlan:
    """Pack (a subset of) a graph's edges into dense tiles.

    Nodes are visited longest-first by default (LPT list scheduling — the same
    greedy order the event-driven NID induces, since long nodes start early and
    short nodes backfill slots). Packing is first-fit into the current tile;
    a node overflowing the tile is split (partial response). Segment budget per
    tile bounds the scatter fan-out.
    """
    if node_ids is None:
        node_ids = np.arange(g.num_nodes, dtype=np.int64)
    else:
        node_ids = np.asarray(node_ids, np.int64)
    deg = g.degrees
    if coeff is None:
        coeff = np.ones(g.num_edges, np.float32)
    if segments_per_tile is None:
        # A tile can hold up to one segment per lane (all degree-1 nodes), so a
        # full segment budget keeps lane occupancy ~1 regardless of degree mix;
        # callers with scatter-bandwidth concerns can lower it.
        segments_per_tile = edges_per_tile

    order = node_ids
    if sort_by_degree:
        order = node_ids[np.argsort(-deg[node_ids], kind="stable")]

    E, S = edges_per_tile, segments_per_tile
    tiles_g: List[np.ndarray] = []  # per-tile gather idx
    tiles_c: List[np.ndarray] = []
    tiles_s: List[np.ndarray] = []
    tiles_o: List[np.ndarray] = []
    tiles_e: List[np.ndarray] = []  # per-tile edge ids (-1 padding)

    cur_g = np.zeros(E, np.int32)
    cur_c = np.zeros(E, np.float32)
    cur_s = np.full(E, S - 1, np.int32)
    cur_o = np.full(S, g.num_nodes, np.int32)
    cur_e = np.full(E, -1, np.int32)
    lane = 0
    seg = 0
    total_edges = 0

    def flush():
        nonlocal cur_g, cur_c, cur_s, cur_o, cur_e, lane, seg
        tiles_g.append(cur_g)
        tiles_c.append(cur_c)
        tiles_s.append(cur_s)
        tiles_o.append(cur_o)
        tiles_e.append(cur_e)
        cur_g = np.zeros(E, np.int32)
        cur_c = np.zeros(E, np.float32)
        cur_s = np.full(E, S - 1, np.int32)
        cur_o = np.full(S, g.num_nodes, np.int32)
        cur_e = np.full(E, -1, np.int32)
        lane = 0
        seg = 0

    for v in order:
        lo, hi = int(g.indptr[v]), int(g.indptr[v + 1])
        nbrs = g.indices[lo:hi]
        cfs = coeff[lo:hi]
        pos = 0
        d = hi - lo
        if d == 0:
            continue  # zero-degree nodes contribute nothing; output row stays 0
        total_edges += d
        while pos < d:
            if lane >= E or seg >= S:
                flush()
            take = min(d - pos, E - lane)
            cur_g[lane : lane + take] = nbrs[pos : pos + take]
            cur_c[lane : lane + take] = cfs[pos : pos + take]
            cur_s[lane : lane + take] = seg
            cur_e[lane : lane + take] = np.arange(lo + pos, lo + pos + take)
            cur_o[seg] = v
            lane += take
            pos += take
            seg += 1  # a split node re-opens a fresh segment in the next tile
    if lane > 0 or seg > 0:
        flush()
    if not tiles_g:  # empty graph: one all-padding tile keeps shapes static
        flush()

    return EdgeTilePlan(
        gather_idx=np.stack(tiles_g),
        coeff=np.stack(tiles_c),
        seg_ids=np.stack(tiles_s),
        out_node=np.stack(tiles_o),
        node_ids=node_ids.astype(np.int32),
        edge_ids=np.stack(tiles_e),
        num_nodes=g.num_nodes,
        edges_per_tile=E,
        segments_per_tile=S,
        total_edges=total_edges,
    )


def concat_tile_plans(
    plans: Sequence[EdgeTilePlan],
    node_offsets: Sequence[int],
    *,
    num_nodes: int,
    min_tiles: int = 0,
    edge_offsets: Optional[Sequence[int]] = None,
) -> EdgeTilePlan:
    """Stack member tile plans into one union plan by offsetting node ids.

    This is the incremental half of padded disjoint-union batching: each
    member graph's tiles were packed once (and cached) by
    ``build_edge_tile_plan``; composing a new batch is pure array relabelling
    — member ``k``'s gather/out indices shift by ``node_offsets[k]``, its
    segment sentinel (the member's node count) is remapped to the union
    sentinel ``num_nodes`` — so no planner runs however the admission window
    recomposes the batch. The cost is that each member's last, partially
    filled tile keeps its padding lanes (bounded by one tile per member).

    ``edge_offsets`` relabels each member's ``edge_ids`` into the union's
    edge index space (one offset per member: the cumulative edge count of
    the member *graphs* before it — not of the plans, which may cover a
    precision subset of their graph's edges). Valid lanes shift by the
    offset; padding lanes stay -1. Omitted, the union plan's ``edge_ids``
    are all -1: structurally complete but opted out of runtime
    coefficients (the historical behaviour).

    ``min_tiles`` pads the stacked plan with all-invalid tiles (coeff 0,
    sentinel segments, edge id -1) up to a tile-count bucket, giving
    recurring device shapes across batches in the same size class.
    """
    if not plans:
        raise ValueError("concat_tile_plans of no plans")
    if len(plans) != len(node_offsets):
        raise ValueError("one node offset per member plan required")
    if edge_offsets is not None and len(plans) != len(edge_offsets):
        raise ValueError("one edge offset per member plan required")
    E = plans[0].edges_per_tile
    S = plans[0].segments_per_tile
    for p in plans:
        if p.edges_per_tile != E or p.segments_per_tile != S:
            raise ValueError("member plans disagree on tile geometry")
    gather, coeff, segs, outs, node_ids, eids = [], [], [], [], [], []
    total_edges = 0
    for k, (p, off) in enumerate(zip(plans, node_offsets)):
        off = int(off)
        if off + p.num_nodes > num_nodes:
            raise ValueError(
                f"member plan spans nodes [{off}, {off + p.num_nodes}) beyond "
                f"union num_nodes {num_nodes}"
            )
        # Invalid lanes (coeff 0) keep whatever row they point at — offsetting
        # them too is safe and keeps this a single vectorised add.
        gather.append(p.gather_idx.astype(np.int64) + off)
        coeff.append(p.coeff)
        segs.append(p.seg_ids)
        outs.append(
            np.where(p.out_node == p.num_nodes, num_nodes, p.out_node + off)
        )
        node_ids.append(p.node_ids.astype(np.int64) + off)
        if edge_offsets is None:
            eids.append(np.full(p.edge_ids.shape, -1, np.int64))
        else:
            e_off = int(edge_offsets[k])
            eids.append(
                np.where(p.edge_ids < 0, -1, p.edge_ids.astype(np.int64) + e_off)
            )
        total_edges += p.total_edges
    n_tiles = sum(p.num_tiles for p in plans)
    if min_tiles > n_tiles:
        pad = min_tiles - n_tiles
        gather.append(np.zeros((pad, E), np.int64))
        coeff.append(np.zeros((pad, E), np.float32))
        segs.append(np.full((pad, E), S - 1, np.int32))
        outs.append(np.full((pad, S), num_nodes, np.int64))
        eids.append(np.full((pad, E), -1, np.int64))
    return EdgeTilePlan(
        gather_idx=np.concatenate(gather).astype(np.int32),
        coeff=np.concatenate(coeff),
        seg_ids=np.concatenate(segs).astype(np.int32),
        out_node=np.concatenate(outs).astype(np.int32),
        node_ids=np.concatenate(node_ids).astype(np.int32),
        edge_ids=np.concatenate(eids).astype(np.int32),
        num_nodes=num_nodes,
        edges_per_tile=E,
        segments_per_tile=S,
        total_edges=total_edges,
    )


# ---------------------------------------------------------------------------
# Degree buckets (power-of-two capacities)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Bucket:
    capacity: int
    node_ids: np.ndarray  # int32[M]
    gather_idx: np.ndarray  # int32[M, capacity]
    coeff: np.ndarray  # f32[M, capacity] (0 on padding lanes)

    @property
    def num_nodes(self) -> int:
        return int(self.node_ids.shape[0])


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    buckets: Tuple[Bucket, ...]
    num_nodes: int

    @property
    def lane_occupancy(self) -> float:
        lanes = sum(b.gather_idx.size for b in self.buckets)
        edges = sum(int((b.coeff != 0).sum()) for b in self.buckets)
        return edges / lanes if lanes else 1.0


def build_bucket_plan(
    g: Graph,
    *,
    max_capacity: int = 1 << 14,
    coeff: Optional[np.ndarray] = None,
    node_ids: Optional[np.ndarray] = None,
) -> BucketPlan:
    """Group nodes into power-of-two-capacity degree buckets.

    A node of degree d lands in the bucket of capacity 2^⌈log2 d⌉ (≥ that
    degree); nodes above ``max_capacity`` are clamped into the top bucket and
    split across rows (rare hubs). Lane waste is < 2× by construction.
    """
    if node_ids is None:
        node_ids = np.arange(g.num_nodes, dtype=np.int64)
    else:
        node_ids = np.asarray(node_ids, np.int64)
    if coeff is None:
        coeff = np.ones(g.num_edges, np.float32)
    deg = g.degrees[node_ids]
    buckets: List[Bucket] = []
    active = node_ids[deg > 0]
    if active.size:
        adeg = g.degrees[active]
        caps = 1 << np.ceil(np.log2(adeg.clip(min=1))).astype(np.int64)
        caps = caps.clip(min=1, max=max_capacity)
        for cap in np.unique(caps):
            sel = active[caps == cap]
            rows: List[np.ndarray] = []
            cfr: List[np.ndarray] = []
            ids: List[int] = []
            for v in sel:
                lo, hi = int(g.indptr[v]), int(g.indptr[v + 1])
                nbrs, cfs = g.indices[lo:hi], coeff[lo:hi]
                for pos in range(0, hi - lo, int(cap)):
                    chunk = nbrs[pos : pos + int(cap)]
                    cchunk = cfs[pos : pos + int(cap)]
                    row = np.zeros(int(cap), np.int32)
                    crow = np.zeros(int(cap), np.float32)
                    row[: chunk.size] = chunk
                    crow[: cchunk.size] = cchunk
                    rows.append(row)
                    cfr.append(crow)
                    ids.append(int(v))
            buckets.append(
                Bucket(
                    capacity=int(cap),
                    node_ids=np.asarray(ids, np.int32),
                    gather_idx=np.stack(rows),
                    coeff=np.stack(cfr),
                )
            )
    return BucketPlan(buckets=tuple(buckets), num_nodes=g.num_nodes)


# ---------------------------------------------------------------------------
# Double-buffered baseline (HyGCN-style): fixed batches, max-degree padding
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PaddedPlan:
    """Batches of ``batch_size`` nodeslots padded to the batch max degree."""

    batches: Tuple[Bucket, ...]  # reuse Bucket container (capacity = batch max)
    num_nodes: int
    batch_size: int

    @property
    def pipeline_gap_ratio(self) -> float:
        """Fraction of lane-cycles wasted waiting on the batch straggler."""
        lanes = sum(b.gather_idx.size for b in self.batches)
        edges = sum(int((b.coeff != 0).sum()) for b in self.batches)
        return 1.0 - (edges / lanes) if lanes else 0.0


def build_padded_plan(
    g: Graph,
    *,
    batch_size: int = 64,
    coeff: Optional[np.ndarray] = None,
    node_ids: Optional[np.ndarray] = None,
) -> PaddedPlan:
    """The double-buffering baseline: node order as given (no degree sort —
    HyGCN streams nodes in id order), each batch padded to its max degree."""
    if node_ids is None:
        node_ids = np.arange(g.num_nodes, dtype=np.int64)
    else:
        node_ids = np.asarray(node_ids, np.int64)
    if coeff is None:
        coeff = np.ones(g.num_edges, np.float32)
    batches: List[Bucket] = []
    for start in range(0, node_ids.size, batch_size):
        sel = node_ids[start : start + batch_size]
        cap = int(g.degrees[sel].max()) if sel.size else 1
        cap = max(cap, 1)
        gi = np.zeros((sel.size, cap), np.int32)
        cf = np.zeros((sel.size, cap), np.float32)
        for r, v in enumerate(sel):
            lo, hi = int(g.indptr[v]), int(g.indptr[v + 1])
            gi[r, : hi - lo] = g.indices[lo:hi]
            cf[r, : hi - lo] = coeff[lo:hi]
        batches.append(
            Bucket(
                capacity=cap,
                node_ids=sel.astype(np.int32),
                gather_idx=gi,
                coeff=cf,
            )
        )
    return PaddedPlan(
        batches=tuple(batches), num_nodes=g.num_nodes, batch_size=batch_size
    )


# ---------------------------------------------------------------------------
# Mixed precision: one plan per Degree-Quant precision group
# ---------------------------------------------------------------------------


def build_mixed_precision_plans(
    g: Graph,
    precision_tags: np.ndarray,
    *,
    edges_per_tile: int = 256,
    segments_per_tile: Optional[int] = None,
    coeff: Optional[np.ndarray] = None,
) -> Dict[str, EdgeTilePlan]:
    """Split nodes by precision tag and build an EdgeTilePlan per group.

    ``precision_tags``: array[N] of strings or small ints; conventionally
    ``"float"`` for Degree-Quant-protected nodes and ``"int8"`` for the rest
    (Table 2's Precision column). Empty groups are omitted.
    """
    precision_tags = np.asarray(precision_tags)
    plans: Dict[str, EdgeTilePlan] = {}
    for tag in np.unique(precision_tags):
        ids = np.nonzero(precision_tags == tag)[0]
        if ids.size == 0:
            continue
        plans[str(tag)] = build_edge_tile_plan(
            g,
            edges_per_tile=edges_per_tile,
            segments_per_tile=segments_per_tile,
            coeff=coeff,
            node_ids=ids,
        )
    return plans


# ---------------------------------------------------------------------------
# Chunk-access schedule — the prefetcher's programming (out-of-core serving)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChunkSchedule:
    """An EdgeTilePlan annotated with the feature chunks each tile gathers.

    This is the host-side programming of the prefetcher (§3.3): the feature
    matrix is split into ``chunk_rows``-row chunks, every tile is annotated
    with the sorted chunk ids its gather lanes touch (all lanes, including
    invalid coeff-0 lanes — those still read a row, and their ±0 products
    must reproduce bitwise), and tiles are emitted in an execution ``order``
    chosen to raise chunk reuse between consecutive tiles.

    ``order`` only ever permutes whole *runs* (see :func:`tile_runs`): a node
    split across tiles lands in consecutive tiles, so keeping runs intact
    preserves each output row's scatter-add order — the streamed executor is
    bitwise-identical to the in-memory scan however the runs are permuted.
    """

    chunk_rows: int
    num_chunks: int
    order: np.ndarray  # int64[T] tile execution order (permutes whole runs)
    tile_chunks: Tuple[np.ndarray, ...]  # per plan-tile sorted unique chunk ids
    runs: np.ndarray  # int64[R+1] run boundaries over plan tile indices
    # Precomputed per-lane (chunk, offset) split of every tile's gather
    # indices — plan-static, so warm streamed requests skip the divmod the
    # prefetcher used to redo per tile per request.
    lane_chunk: np.ndarray  # int32[T, E] gather_idx // chunk_rows
    lane_off: np.ndarray  # int32[T, E] gather_idx % chunk_rows

    @property
    def num_tiles(self) -> int:
        return int(self.order.shape[0])

    @property
    def num_runs(self) -> int:
        return int(self.runs.shape[0]) - 1

    @property
    def total_chunk_visits(self) -> int:
        """Σ over tiles of chunks touched — uploads if nothing were cached."""
        return int(sum(c.size for c in self.tile_chunks))

    def max_tile_chunks(self) -> int:
        """Largest single-tile working set (waves needed = ceil(this/slots))."""
        return int(max((c.size for c in self.tile_chunks), default=0))


def tile_runs(plan: EdgeTilePlan) -> np.ndarray:
    """Boundaries of split-chains: maximal spans of tiles sharing an out node.

    ``build_edge_tile_plan`` splits an overflowing node across *consecutive*
    tiles (the partial-response mechanism), so a run is the unit that may be
    reordered without perturbing any output row's accumulation order: within
    a run the split node's partial sums stay in tile order, and no node spans
    two runs. Returns int64[num_runs + 1] half-open boundaries.
    """
    T = plan.num_tiles
    bounds = [0]
    sentinel = plan.num_nodes
    for t in range(1, T):
        prev = plan.out_node[t - 1]
        cur = plan.out_node[t]
        prev_valid = prev[prev != sentinel]
        cur_valid = cur[cur != sentinel]
        if prev_valid.size and cur_valid.size and np.intersect1d(
            prev_valid, cur_valid, assume_unique=False
        ).size:
            continue  # a node spans the boundary: same run
        bounds.append(t)
    bounds.append(T)
    return np.asarray(bounds, np.int64)


def split_plan_by_halo(
    plan: EdgeTilePlan, num_owned: int
) -> Tuple[EdgeTilePlan, EdgeTilePlan]:
    """Split a shard-local tile plan into (interior, boundary) halves.

    *Interior* tiles gather only owned rows (local id < ``num_owned``);
    *boundary* tiles touch at least one halo source. The split is at **run**
    granularity (``tile_runs``): a node split across consecutive tiles stays
    within one run, so every output row's partial sums live entirely in one
    half and executing interior-then-boundary (the boundary scan continuing
    from the interior output buffer) reproduces the unsplit scan **bitwise**
    — the property the overlapped halo exchange relies on. The interior half
    can therefore run before the halo rows arrive (they may be zeros), which
    is what hides the exchange latency.

    Padding lanes (edge id −1 / coeff 0) gather row 0 and never force a run
    into the boundary half. Either half may be empty (0 tiles).
    """
    bounds = tile_runs(plan)
    real = (
        plan.edge_ids >= 0
        if plan.edge_ids is not None
        else plan.coeff != 0
    )
    touches_halo = np.any(real & (plan.gather_idx >= num_owned), axis=1)
    interior_tiles: list = []
    boundary_tiles: list = []
    for r in range(bounds.shape[0] - 1):
        t0, t1 = int(bounds[r]), int(bounds[r + 1])
        dest = boundary_tiles if np.any(touches_halo[t0:t1]) else interior_tiles
        dest.extend(range(t0, t1))

    def subset(tiles) -> EdgeTilePlan:
        idx = np.asarray(tiles, np.int64)
        return dataclasses.replace(
            plan,
            gather_idx=plan.gather_idx[idx],
            coeff=plan.coeff[idx],
            seg_ids=plan.seg_ids[idx],
            out_node=plan.out_node[idx],
            edge_ids=(
                plan.edge_ids[idx] if plan.edge_ids is not None else None
            ),
            total_edges=int(np.sum(real[idx])) if idx.size else 0,
        )

    return subset(interior_tiles), subset(boundary_tiles)


def build_chunk_schedule(
    plan: EdgeTilePlan,
    chunk_rows: int,
    *,
    reorder: bool = True,
) -> ChunkSchedule:
    """Annotate a tile plan with chunk accesses and a locality-aware order.

    The reordering pass sorts *runs* by the median chunk id their tiles
    gather from — runs whose accesses centre on the same region of the
    feature matrix execute back-to-back, so a budget-bound chunk cache sees
    longer reuse chains (an O(T log T) clustering heuristic; Belady eviction
    in the prefetcher does the rest). ``reorder=False`` keeps plan order
    (useful as the control arm when measuring the reordering win).
    """
    if chunk_rows <= 0:
        raise ValueError("chunk_rows must be positive")
    num_chunks = -(-max(plan.num_nodes, 1) // chunk_rows)
    gi = plan.gather_idx.astype(np.int64)
    lane_chunk = (gi // chunk_rows).astype(np.int32)
    lane_off = (gi % chunk_rows).astype(np.int32)
    tile_chunks = tuple(
        np.unique(lane_chunk[t]).astype(np.int64) for t in range(plan.num_tiles)
    )
    runs = tile_runs(plan)
    order = np.arange(plan.num_tiles, dtype=np.int64)
    if reorder and runs.size > 2:
        keys = []
        for r in range(runs.size - 1):
            lo, hi = int(runs[r]), int(runs[r + 1])
            touched = np.concatenate([tile_chunks[t] for t in range(lo, hi)])
            keys.append(float(np.median(touched)) if touched.size else 0.0)
        run_order = np.argsort(np.asarray(keys), kind="stable")
        order = np.concatenate(
            [np.arange(runs[r], runs[r + 1], dtype=np.int64) for r in run_order]
        )
    return ChunkSchedule(
        chunk_rows=int(chunk_rows),
        num_chunks=int(num_chunks),
        order=order,
        tile_chunks=tile_chunks,
        runs=runs,
        lane_chunk=lane_chunk,
        lane_off=lane_off,
    )


# ---------------------------------------------------------------------------
# Generic segment packing — reused by MoE token->expert dispatch
# ---------------------------------------------------------------------------


def pack_segments(
    lengths: Sequence[int], capacity: int
) -> Tuple[np.ndarray, np.ndarray, int]:
    """First-fit-decreasing packing of variable-length segments into tiles.

    Returns ``(tile_of_segment, offset_of_segment, num_tiles)`` where segment i
    occupies lanes ``[offset, offset+len)`` of its tile, possibly spanning
    multiple tiles when len > remaining capacity (partial response). Used by
    the MoE dispatcher to bound expert-capacity padding the same way the
    nodeslot scheduler bounds degree padding.
    """
    lengths = np.asarray(lengths, np.int64)
    order = np.argsort(-lengths, kind="stable")
    tile_of = np.zeros(lengths.size, np.int64)
    offset_of = np.zeros(lengths.size, np.int64)
    tile, lane = 0, 0
    for i in order:
        ln = int(lengths[i])
        if ln > capacity - lane:
            tile += 1
            lane = 0
        tile_of[i], offset_of[i] = tile, lane
        lane += ln
        while lane > capacity:  # segment longer than a whole tile: spill
            tile += 1
            lane -= capacity
    num_tiles = tile + (1 if lane > 0 else 0)
    return tile_of, offset_of, max(num_tiles, 1)


# ---------------------------------------------------------------------------
# Locality-aware tile packing — rebuild tile membership around feature chunks
# ---------------------------------------------------------------------------


def pack_tiles_by_chunk(plan: EdgeTilePlan, chunk_rows: int) -> EdgeTilePlan:
    """Repack a tile plan so co-tiled edges share source feature chunks.

    ``build_chunk_schedule(reorder=True)`` only permutes whole runs, so a hit
    rate ceiling remains: tile membership was fixed by degree order, and on
    graphs without neighborhood structure every tile touches most chunks.
    This pass rebuilds tile membership around the chunk axis instead. Each
    single-tile run is decomposed into its per-node segment spans (the unit
    that can move without perturbing any output row's accumulation order),
    units are bucketed by their mean source chunk and packed first-fit-
    decreasing (:func:`pack_segments`) into fresh tiles, and buckets are
    emitted in chunk order, so consecutive tiles draw from the same region of
    the feature matrix. Multi-tile runs (nodes split across tiles) are
    atomic: their tiles are copied verbatim and the block is ordered among
    the buckets by its mean touched chunk.

    Bitwise contract with the unpacked plan: every output row accumulates
    the same lane products in the same order. A unit's lanes move as one
    contiguous block (the intra-segment sum is unchanged); a tile that used
    all ``S`` segments carries its trailing padding lanes along with the
    last unit, because the in-memory scan folds their signed-zero products
    into that segment's partial sum; and fresh padding in packed tiles maps
    to the sentinel segment, whose partial sum the executor discards (its
    gather index points at a row the tile already reads, so padding never
    drags a foreign chunk into the tile's working set). Plans with
    ``segments_per_tile == 1`` have no sentinel segment to give fresh
    padding and are returned unchanged.
    """
    E, S = plan.edges_per_tile, plan.segments_per_tile
    T = plan.num_tiles
    if S < 2 or T <= 1 or chunk_rows <= 0:
        return plan
    sentinel = plan.num_nodes
    lane_chunk = plan.gather_idx.astype(np.int64) // chunk_rows
    valid = plan.edge_ids >= 0
    runs = tile_runs(plan)

    # blocks: (sort key, kind, payload). "verbatim" payload = (lo, hi) tile
    # span of a multi-tile run; "pack" payload = unit indices of one new tile.
    blocks: List[Tuple[float, str, object]] = []
    single: List[int] = []
    n_empty = 0  # all-padding tiles (union size-class filler): re-appended
    for r in range(runs.size - 1):
        lo, hi = int(runs[r]), int(runs[r + 1])
        if hi - lo > 1:
            v = valid[lo:hi]
            key = float(lane_chunk[lo:hi][v].mean()) if v.any() else 0.0
            blocks.append((key, "verbatim", (lo, hi)))
        elif bool((plan.out_node[lo] == sentinel).all()):
            n_empty += 1
        else:
            single.append(lo)

    # Per-segment lane spans of the single-tile runs, extracted in one flat
    # pass: a span starts where the segment id changes (or a tile begins).
    # Trailing padding lanes share segment id S-1, so when a tile used all S
    # segments they merge into the last real span automatically — exactly
    # the lanes whose products the in-memory scan folds into that segment.
    u_tile = u_start = u_len = u_out = u_key = np.zeros(0, np.int64)
    if single:
        single_arr = np.asarray(single, np.int64)
        K = single_arr.size
        s_flat = plan.seg_ids[single_arr].astype(np.int64).ravel()
        tid = np.repeat(np.arange(K, dtype=np.int64), E)
        is_start = np.ones(K * E, bool)
        is_start[1:] = (s_flat[1:] != s_flat[:-1]) | (tid[1:] != tid[:-1])
        starts = np.flatnonzero(is_start)
        lens = np.diff(np.append(starts, K * E))
        span_tile = single_arr[tid[starts]]
        span_seg = s_flat[starts]
        span_out = plan.out_node[span_tile, span_seg].astype(np.int64)
        ch_flat = lane_chunk[single_arr].ravel()
        v_flat = valid[single_arr].ravel()
        ch_sum = np.add.reduceat(np.where(v_flat, ch_flat, 0), starts)
        v_cnt = np.add.reduceat(v_flat.astype(np.int64), starts)
        real = span_out != sentinel  # pure-padding spans are dropped
        u_tile = span_tile[real]
        u_start = (starts - tid[starts] * E)[real]
        u_len = lens[real]
        u_out = span_out[real]
        u_key = ch_sum[real] // np.maximum(v_cnt[real], 1)

    # Bucket units by mean source chunk; FFD-pack each bucket into tiles.
    # A packed tile holds at most S-1 units so segment S-1 stays sentinel
    # (fresh padding must never pollute a real segment's sum).
    max_units = max(S - 1, 1)
    for ckey in np.unique(u_key):
        sel = np.flatnonzero(u_key == ckey)
        tile_of, _, ntiles = pack_segments(u_len[sel], E)
        groups: List[List[int]] = [[] for _ in range(ntiles)]
        for j, i in enumerate(sel):
            groups[int(tile_of[j])].append(int(i))
        if any(len(gr) > max_units for gr in groups):
            # Rare (more than S-1 units fit in E lanes): greedy longest-first
            # refill under both the lane and the segment budget.
            groups = []
            cur: List[int] = []
            lanes = 0
            for i in sel[np.argsort(-u_len[sel], kind="stable")]:
                ln = int(u_len[i])
                if cur and (lanes + ln > E or len(cur) >= max_units):
                    groups.append(cur)
                    cur, lanes = [], 0
                cur.append(int(i))
                lanes += ln
            if cur:
                groups.append(cur)
        for gr in groups:
            if gr:
                blocks.append((float(ckey), "pack", gr))
    blocks.sort(key=lambda b: b[0])

    n_pack = sum(1 for b in blocks if b[1] == "pack")
    n_verb = sum(b[2][1] - b[2][0] for b in blocks if b[1] == "verbatim")
    newT = max(n_pack + n_verb + n_empty, 1)
    new_g = np.zeros((newT, E), np.int32)
    new_c = np.zeros((newT, E), np.float32)
    new_s = np.full((newT, E), S - 1, np.int32)
    new_o = np.full((newT, S), sentinel, np.int32)
    new_e = np.full((newT, E), -1, np.int32)

    # Layout pass: verbatim blocks copy whole tiles; packed tiles record one
    # (unit -> destination lane/segment) placement each, copied flat below.
    p_unit: List[int] = []
    p_dst_tile: List[int] = []
    p_dst_off: List[int] = []
    p_seg: List[int] = []
    pack_fill: List[Tuple[int, int]] = []  # (tile, lanes used)
    dst = 0
    for _, kind, payload in blocks:
        if kind == "verbatim":
            lo, hi = payload  # type: ignore[misc]
            n = hi - lo
            new_g[dst : dst + n] = plan.gather_idx[lo:hi]
            new_c[dst : dst + n] = plan.coeff[lo:hi]
            new_s[dst : dst + n] = plan.seg_ids[lo:hi]
            new_o[dst : dst + n] = plan.out_node[lo:hi]
            new_e[dst : dst + n] = plan.edge_ids[lo:hi]
            dst += n
        else:
            off = 0
            for si, i in enumerate(payload):  # type: ignore[arg-type]
                p_unit.append(i)
                p_dst_tile.append(dst)
                p_dst_off.append(off)
                p_seg.append(si)
                off += int(u_len[i])
            pack_fill.append((dst, off))
            dst += 1

    if p_unit:
        idx = np.asarray(p_unit, np.int64)
        dt = np.asarray(p_dst_tile, np.int64)
        do = np.asarray(p_dst_off, np.int64)
        sg = np.asarray(p_seg, np.int64)
        lens = u_len[idx]
        total = int(lens.sum())
        within = np.arange(total, dtype=np.int64) - np.repeat(
            np.cumsum(lens) - lens, lens
        )
        src = np.repeat(u_tile[idx] * E + u_start[idx], lens) + within
        dflat = np.repeat(dt * E + do, lens) + within
        new_g.ravel()[dflat] = plan.gather_idx.ravel()[src]
        new_c.ravel()[dflat] = plan.coeff.ravel()[src]
        new_e.ravel()[dflat] = plan.edge_ids.ravel()[src]
        new_s.ravel()[dflat] = np.repeat(sg, lens).astype(np.int32)
        new_o[dt, sg] = u_out[idx].astype(np.int32)
        for t, fill in pack_fill:
            if fill < E:
                new_g[t, fill:] = new_g[t, 0]

    return EdgeTilePlan(
        gather_idx=new_g,
        coeff=new_c,
        seg_ids=new_s,
        out_node=new_o,
        node_ids=plan.node_ids,
        edge_ids=new_e,
        num_nodes=plan.num_nodes,
        edges_per_tile=E,
        segments_per_tile=S,
        total_edges=plan.total_edges,
    )
