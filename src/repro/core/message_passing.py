"""The AMPLE engine facade: graph in → event-driven mixed-precision layer out.

``AmpleEngine`` is the software equivalent of the accelerator's top level
(Figure 1): it owns the planner outputs (NID programming), the precision tags
(Degree-Quant), the aggregation coefficients per model (AGE configuration) and
the weight quantization cache (Weight Bank), and exposes a single
``layer(x, phi/gamma weights)`` entry point the GNN models call per layer.

Message-passing semantics follow Eq. 1:
    x_i' = γ(x_i, A_{j∈N(i)} φ(x_i, x_j, e_ij))
with φ folded into per-edge coefficients for GCN/GIN (φ = c_ij · x_j) and a
dense pre-projection for GraphSAGE (φ = σ(W3 x_j + b)).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as sched
from repro.core.aggregation import (
    aggregate_edge_tiles,
    aggregate_mixed_precision,
    to_device_plan,
)
from repro.core.degree_quant import DegreeQuantConfig, inference_precision_tags
from repro.core.quantization import QuantParams, compute_scale_zp, quantize_per_channel
from repro.core.transformation import (
    transform_dense,
    transform_int8,
    transform_mixed_precision,
)
from repro.graphs.csr import Graph, gcn_norm_coeffs

__all__ = [
    "EngineConfig",
    "ExecutionPlan",
    "compile_plans",
    "aggregation_coefficients",
    "AmpleEngine",
]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    edges_per_tile: int = 256
    segments_per_tile: Optional[int] = None
    mixed_precision: bool = True
    use_kernel: bool = False  # route through Pallas kernels (interpret on CPU)
    dq: DegreeQuantConfig = dataclasses.field(default_factory=DegreeQuantConfig)


def aggregation_coefficients(g: Graph, mode: str) -> np.ndarray:
    """Per-edge coefficients folding the aggregation function into the plan.

      * "sum"  — coeff 1 (GIN)
      * "mean" — coeff 1/deg(i) (GraphSAGE)
      * "gcn"  — coeff 1/√(d̂_i d̂_j) (GCN; self-loops must already be present)
    """
    if mode == "sum":
        return np.ones(g.num_edges, np.float32)
    if mode == "mean":
        deg = np.maximum(g.degrees, 1).astype(np.float32)
        return (1.0 / np.repeat(deg, g.degrees)).astype(np.float32)
    if mode == "gcn":
        return gcn_norm_coeffs(g)
    raise ValueError(f"unknown aggregation mode {mode!r}")


@dataclasses.dataclass(frozen=True, eq=False)
class ExecutionPlan:
    """The compiled, graph-specific half of the engine — NID programming.

    Everything the planner derives from (graph structure, EngineConfig) lives
    here: the Degree-Quant precision tags, the per-precision node groups the
    FTE partitions over, and one mixed-precision tile-plan set per aggregation
    coefficient mode. It holds no jnp state and no weight caches, so it is a
    pure host-side artifact: hashable by fingerprint, safe to share across
    engines, and the unit the serving layer caches (a plan compiled for one
    request is bitwise-valid for every later request on the same structure).
    """

    fingerprint: str
    graph_fp: str  # structure hash of the graph the plan was compiled for
    num_nodes: int
    num_edges: int
    cfg: EngineConfig
    precision_tags: np.ndarray  # str[N]
    node_groups: Mapping[str, np.ndarray]  # tag -> node ids
    mode_plans: Mapping[str, Mapping[str, sched.EdgeTilePlan]]  # mode -> tag -> plan

    def __hash__(self) -> int:
        return hash(self.fingerprint)

    def __eq__(self, other) -> bool:
        return isinstance(other, ExecutionPlan) and other.fingerprint == self.fingerprint

    @property
    def modes(self) -> Tuple[str, ...]:
        return tuple(sorted(self.mode_plans))


def _precision_tags(g: Graph, cfg: EngineConfig) -> np.ndarray:
    if cfg.mixed_precision:
        return inference_precision_tags(g, cfg.dq)
    return np.full(g.num_nodes, "float", dtype=object).astype(str)


def compile_plans(
    g: Graph,
    cfg: Optional[EngineConfig] = None,
    *,
    modes: Sequence[str] = ("sum",),
    precision_tags: Optional[np.ndarray] = None,
) -> ExecutionPlan:
    """Compile a graph into a reusable ExecutionPlan (the expensive host step).

    This is the pure planning half of what ``AmpleEngine.__init__`` + lazy
    ``plans(mode)`` used to do: Degree-Quant tagging plus one edge-tile plan
    set per requested coefficient mode. The result is immutable and keyed by
    ``fingerprint`` = hash(structure, cfg, modes) — identical fingerprints
    mean the planner would emit identical tiles.

    ``precision_tags`` overrides the Degree-Quant tagging (str[N]); the
    serving engine uses this to tag batched disjoint-union graphs per member
    graph rather than union-wide.
    """
    cfg = cfg if cfg is not None else EngineConfig()
    if precision_tags is None:
        tags = _precision_tags(g, cfg)
        tag_part = ""
    else:
        tags = np.asarray(precision_tags)
        if tags.shape != (g.num_nodes,):
            raise ValueError(
                f"precision_tags must be [{g.num_nodes}], got {tags.shape}"
            )
        tag_part = "tags:" + hashlib.blake2b(
            np.asarray(tags, dtype="U8").tobytes(), digest_size=16
        ).hexdigest()
    groups = {
        tag: np.nonzero(tags == tag)[0] for tag in np.unique(tags)
    }
    mode_plans = {
        mode: sched.build_mixed_precision_plans(
            g,
            tags,
            edges_per_tile=cfg.edges_per_tile,
            segments_per_tile=cfg.segments_per_tile,
            coeff=aggregation_coefficients(g, mode),
        )
        for mode in dict.fromkeys(modes)  # dedupe, keep order
    }
    graph_fp = sched.graph_fingerprint(g)
    fp = sched.plan_fingerprint(
        g, repr(cfg), *sorted(dict.fromkeys(modes)), *((tag_part,) if tag_part else ())
    )
    return ExecutionPlan(
        fingerprint=fp,
        graph_fp=graph_fp,
        num_nodes=g.num_nodes,
        num_edges=g.num_edges,
        cfg=cfg,
        precision_tags=tags,
        node_groups=groups,
        mode_plans=mode_plans,
    )


class AmpleEngine:
    """Thin per-graph execution wrapper around an ``ExecutionPlan``.

    The engine owns only transient device-facing state (the weight-quant
    cache); all planning lives in the plan. Construct either way:

      * ``AmpleEngine(g, cfg)`` — compiles tags up front, tile plans lazily
        per aggregation mode (the historical behaviour), or
      * ``AmpleEngine(g, plan=plan)`` — reuses a cached ``compile_plans``
        artifact and skips the planner entirely.
    """

    def __init__(
        self,
        g: Graph,
        cfg: Optional[EngineConfig] = None,
        *,
        plan: Optional[ExecutionPlan] = None,
    ):
        if plan is not None:
            if plan.graph_fp != sched.graph_fingerprint(g):
                raise ValueError(
                    f"plan was compiled for a different graph structure "
                    f"({plan.num_nodes} nodes, {plan.num_edges} edges vs "
                    f"{g.num_nodes}, {g.num_edges}; fingerprints differ)"
                )
            if cfg is not None and cfg != plan.cfg:
                raise ValueError("cfg disagrees with plan.cfg; pass one or the other")
            cfg = plan.cfg
        else:
            cfg = cfg if cfg is not None else EngineConfig()
            plan = compile_plans(g, cfg, modes=())
        self.graph = g
        self.cfg = cfg
        self.plan = plan
        self.precision_tags = plan.precision_tags
        self.node_groups: Dict[str, np.ndarray] = dict(plan.node_groups)
        self._plans: Dict[str, Mapping[str, sched.EdgeTilePlan]] = dict(plan.mode_plans)
        self._wq_cache: Dict[int, tuple] = {}

    # ---------------------------------------------------------------- plans
    def plans(self, mode: str) -> Mapping[str, sched.EdgeTilePlan]:
        if mode not in self._plans:  # lazy extension beyond the compiled modes
            self._plans[mode] = sched.build_mixed_precision_plans(
                self.graph,
                self.precision_tags,
                edges_per_tile=self.cfg.edges_per_tile,
                segments_per_tile=self.cfg.segments_per_tile,
                coeff=aggregation_coefficients(self.graph, mode),
            )
        return self._plans[mode]

    # ----------------------------------------------------------------- AGE
    def aggregate(self, x: jnp.ndarray, *, mode: str = "sum") -> jnp.ndarray:
        """Event-driven mixed-precision aggregation of node embeddings."""
        plans = self.plans(mode)
        if self.cfg.mixed_precision:
            return aggregate_mixed_precision(
                x,
                plans,
                num_nodes=self.graph.num_nodes,
                use_kernel=self.cfg.use_kernel,
            )
        p = plans["float"]
        return aggregate_edge_tiles(
            x,
            to_device_plan(p),
            num_nodes=self.graph.num_nodes,
            segments_per_tile=p.segments_per_tile,
            use_kernel=self.cfg.use_kernel,
        )

    # ----------------------------------------------------------------- FTE
    def _weight_q(self, w: jnp.ndarray):
        key = id(w)
        if key not in self._wq_cache:
            self._wq_cache[key] = quantize_per_channel(w, axis=-1)
        return self._wq_cache[key]

    def transform(
        self,
        h: jnp.ndarray,
        w: jnp.ndarray,
        b: Optional[jnp.ndarray] = None,
        activation: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
    ) -> jnp.ndarray:
        """Mixed-precision transformation of aggregated embeddings."""
        if not self.cfg.mixed_precision:
            return transform_dense(h, w, b, activation)
        w_q, w_qp = self._weight_q(w)
        return transform_mixed_precision(
            h,
            self.node_groups,
            w,
            b,
            activation,
            w_q=w_q,
            w_qp=w_qp,
            use_kernel=self.cfg.use_kernel,
        )

    # ------------------------------------------------------------- metrics
    def occupancy_report(self) -> Dict[str, float]:
        """Lane economics vs the double-buffered baseline (same graph)."""
        plan = sched.build_edge_tile_plan(
            self.graph, edges_per_tile=self.cfg.edges_per_tile
        )
        padded = sched.build_padded_plan(self.graph, batch_size=64)
        return {
            "event_driven_lane_occupancy": plan.lane_occupancy,
            "double_buffer_pipeline_gap_ratio": padded.pipeline_gap_ratio,
            "float_node_ratio": float(
                (self.precision_tags == "float").mean() if self.graph.num_nodes else 0
            ),
        }
