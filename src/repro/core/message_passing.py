"""The AMPLE engine facade: graph in → event-driven mixed-precision layer out.

``AmpleEngine`` is the software equivalent of the accelerator's top level
(Figure 1): it owns the planner outputs (NID programming), the precision tags
(Degree-Quant), the aggregation coefficients per model (AGE configuration) and
the weight quantization cache (Weight Bank), and exposes a single
``layer(x, phi/gamma weights)`` entry point the GNN models call per layer.

Message-passing semantics follow Eq. 1:
    x_i' = γ(x_i, A_{j∈N(i)} φ(x_i, x_j, e_ij))
with φ folded into per-edge coefficients for GCN/GIN (φ = c_ij · x_j) and a
dense pre-projection for GraphSAGE (φ = σ(W3 x_j + b)).
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as sched
from repro.core.aggregation import (
    aggregate_edge_tiles,
    aggregate_mixed_precision,
    edge_segment_sum_tiles,
    segment_max_edge_tiles,
    tile_edge_coeff,
    to_device_plan,
)
from repro.core.degree_quant import DegreeQuantConfig, inference_precision_tags
from repro.core.quantization import (
    QuantParams,
    compute_scale_zp,
    dequantize,
    quantize,
    quantize_per_channel,
)
from repro.core.transformation import (
    transform_dense,
    transform_int8,
    transform_mixed_precision,
)
from repro.graphs.csr import Graph, gcn_norm_coeffs
from repro.observe import trace as otrace
from repro.graphs.partition import (
    Partition,
    ShardSubgraph,
    make_partition,
    partition_by_edges,
    shard_subgraph,
    validate_partition,
)

# repro.memory imports repro.core (scheduler/quantization/transformation), so
# the engine pulls the streamed executors in lazily — a module-level import
# here would deadlock whichever package is imported first.


def _streamed_features_type():
    from repro.memory.prefetcher import StreamedFeatures

    return StreamedFeatures

__all__ = [
    "EngineConfig",
    "ExecutionPlan",
    "ShardPlan",
    "ShardedExecutionPlan",
    "compile_plans",
    "compile_shard_plan",
    "compile_sharded_plans",
    "assemble_union_plan",
    "shard_plan_key",
    "aggregation_coefficients",
    "engine_precision_tags",
    "AmpleEngine",
]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    edges_per_tile: int = 256
    segments_per_tile: Optional[int] = None
    mixed_precision: bool = True
    use_kernel: bool = False  # route through Pallas kernels (interpret on CPU)
    dq: DegreeQuantConfig = dataclasses.field(default_factory=DegreeQuantConfig)


def aggregation_coefficients(g: Graph, mode: str) -> np.ndarray:
    """Per-edge coefficients folding the aggregation function into the plan.

      * "sum"     — coeff 1 (GIN)
      * "mean"    — coeff 1/deg(i) (GraphSAGE)
      * "gcn"     — coeff 1/√(d̂_i d̂_j) (GCN; self-loops must already be present)
      * "runtime" — coeff 1 as a pure lane mask: the real per-edge values
        arrive at request time (GAT attention) and are scattered through the
        plan's ``edge_ids`` indirection, multiplying the static 1s — so the
        compiled plan stays structure-keyed while coefficients change every
        request.
    """
    if mode in ("sum", "runtime"):
        return np.ones(g.num_edges, np.float32)
    if mode == "mean":
        deg = np.maximum(g.degrees, 1).astype(np.float32)
        return (1.0 / np.repeat(deg, g.degrees)).astype(np.float32)
    if mode == "gcn":
        return gcn_norm_coeffs(g)
    raise ValueError(f"unknown aggregation mode {mode!r}")


@dataclasses.dataclass(frozen=True, eq=False)
class ExecutionPlan:
    """The compiled, graph-specific half of the engine — NID programming.

    Everything the planner derives from (graph structure, EngineConfig) lives
    here: the Degree-Quant precision tags, the per-precision node groups the
    FTE partitions over, and one mixed-precision tile-plan set per aggregation
    coefficient mode. It holds no jnp state and no weight caches, so it is a
    pure host-side artifact: hashable by fingerprint, safe to share across
    engines, and the unit the serving layer caches (a plan compiled for one
    request is bitwise-valid for every later request on the same structure).
    """

    fingerprint: str
    graph_fp: str  # structure hash of the graph the plan was compiled for
    num_nodes: int
    num_edges: int
    cfg: EngineConfig
    precision_tags: np.ndarray  # str[N]
    node_groups: Mapping[str, np.ndarray]  # tag -> node ids
    mode_plans: Mapping[str, Mapping[str, sched.EdgeTilePlan]]  # mode -> tag -> plan

    def __hash__(self) -> int:
        return hash(self.fingerprint)

    def __eq__(self, other) -> bool:
        return isinstance(other, ExecutionPlan) and other.fingerprint == self.fingerprint

    @property
    def modes(self) -> Tuple[str, ...]:
        return tuple(sorted(self.mode_plans))


def engine_precision_tags(g: Graph, cfg: EngineConfig) -> np.ndarray:
    """The precision tags the planner would assign under ``cfg`` (str[N])."""
    if cfg.mixed_precision:
        return inference_precision_tags(g, cfg.dq)
    return np.full(g.num_nodes, "float", dtype=object).astype(str)


def compile_plans(
    g: Graph,
    cfg: Optional[EngineConfig] = None,
    *,
    modes: Sequence[str] = ("sum",),
    precision_tags: Optional[np.ndarray] = None,
    coeffs: Optional[Mapping[str, np.ndarray]] = None,
) -> ExecutionPlan:
    """Compile a graph into a reusable ExecutionPlan (the expensive host step).

    This is the pure planning half of what ``AmpleEngine.__init__`` + lazy
    ``plans(mode)`` used to do: Degree-Quant tagging plus one edge-tile plan
    set per requested coefficient mode. The result is immutable and keyed by
    ``fingerprint`` = hash(structure, cfg, modes) — identical fingerprints
    mean the planner would emit identical tiles.

    ``precision_tags`` overrides the Degree-Quant tagging (str[N]); the
    serving engine uses this to tag batched disjoint-union graphs per member
    graph rather than union-wide. ``coeffs`` overrides the per-edge
    aggregation coefficients per mode (f32[E] aligned with ``g.indices``);
    shard-local plans pass slices of globally computed coefficients here,
    since e.g. GCN normalisation needs the *global* degree of halo sources.
    Overridden tags/coeffs are folded into the fingerprint.
    """
    cfg = cfg if cfg is not None else EngineConfig()
    if precision_tags is None:
        tags = engine_precision_tags(g, cfg)
        tag_part = ""
    else:
        tags = np.asarray(precision_tags)
        if tags.shape != (g.num_nodes,):
            raise ValueError(
                f"precision_tags must be [{g.num_nodes}], got {tags.shape}"
            )
        tag_part = "tags:" + hashlib.blake2b(
            np.asarray(tags, dtype="U8").tobytes(), digest_size=16
        ).hexdigest()
    groups = {
        tag: np.nonzero(tags == tag)[0] for tag in np.unique(tags)
    }

    def mode_coeff(mode: str) -> np.ndarray:
        if coeffs is not None and mode in coeffs:
            c = np.asarray(coeffs[mode], np.float32)
            if c.shape != (g.num_edges,):
                raise ValueError(f"coeffs[{mode!r}] must be [{g.num_edges}], got {c.shape}")
            return c
        return aggregation_coefficients(g, mode)

    mode_plans = {
        mode: sched.build_mixed_precision_plans(
            g,
            tags,
            edges_per_tile=cfg.edges_per_tile,
            segments_per_tile=cfg.segments_per_tile,
            coeff=mode_coeff(mode),
        )
        for mode in dict.fromkeys(modes)  # dedupe, keep order
    }
    coeff_part = ""
    if coeffs is not None:
        h = hashlib.blake2b(digest_size=16)
        for mode in sorted(set(coeffs) & set(dict.fromkeys(modes))):
            h.update(mode.encode())
            h.update(np.ascontiguousarray(coeffs[mode], np.float32).tobytes())
        coeff_part = "coeffs:" + h.hexdigest()
    graph_fp = sched.graph_fingerprint(g)
    fp = sched.plan_fingerprint(
        g,
        repr(cfg),
        *sorted(dict.fromkeys(modes)),
        *((tag_part,) if tag_part else ()),
        *((coeff_part,) if coeff_part else ()),
    )
    return ExecutionPlan(
        fingerprint=fp,
        graph_fp=graph_fp,
        num_nodes=g.num_nodes,
        num_edges=g.num_edges,
        cfg=cfg,
        precision_tags=tags,
        node_groups=groups,
        mode_plans=mode_plans,
    )


def assemble_union_plan(
    member_plans: Sequence[ExecutionPlan],
    union: Graph,
    *,
    cfg: Optional[EngineConfig] = None,
    edge_bucket: int = 0,
) -> ExecutionPlan:
    """Compose per-member ExecutionPlans into one padded disjoint-union plan.

    The incremental counterpart of ``compile_plans``: each member graph was
    planned once (Degree-Quant tags + edge tiles, both exactly as if served
    solo) and the union plan is assembled by index relabelling
    (``scheduler.concat_tile_plans``) — O(E) array copies, no planner. The
    admission loop of the continuous-batching engine leans on this: a new
    batch composition over known member structures costs assembly, not
    planning.

    ``union`` is the (possibly node-padded) disjoint union of the members'
    *prepared* graphs, in member order; padding nodes beyond the members are
    isolated, carry no plan tiles, and are excluded from the transform node
    groups, so their rows stay exactly zero through every layer — batch-wide
    int8 activation scales never see them. ``edge_bucket`` rounds each
    per-(mode, tag) tile stack up to the size-class tile count so device
    shapes recur across member mixes.
    """
    if not member_plans:
        raise ValueError("assemble_union_plan of no member plans")
    cfg = cfg if cfg is not None else member_plans[0].cfg
    for p in member_plans:
        if p.cfg != cfg:
            raise ValueError("member plans were compiled under a different EngineConfig")
    modes = member_plans[0].modes
    for p in member_plans[1:]:
        if p.modes != modes:
            raise ValueError("member plans disagree on aggregation modes")
    offsets = np.cumsum([0] + [p.num_nodes for p in member_plans])
    edge_offsets = np.cumsum([0] + [p.num_edges for p in member_plans])
    n_real = int(offsets[-1])
    if n_real > union.num_nodes:
        raise ValueError(
            f"member plans cover {n_real} nodes but union has {union.num_nodes}"
        )
    n_pad = union.num_nodes - n_real

    tags = np.concatenate(
        [np.asarray(p.precision_tags, dtype="U8") for p in member_plans]
        + ([np.full(n_pad, "pad", dtype="U8")] if n_pad else [])
    )
    # Padding nodes belong to no precision group: the FTE streams skip their
    # rows (they stay 0), so batch-wide activation calibration matches the
    # unpadded union's exactly.
    groups = {
        tag: np.nonzero(tags == tag)[0]
        for tag in np.unique(tags)
        if tag != "pad"
    }

    mode_plans: Dict[str, Dict[str, sched.EdgeTilePlan]] = {}
    for mode in modes:
        per_tag: Dict[str, sched.EdgeTilePlan] = {}
        tag_names = sorted(
            {t for p in member_plans for t in p.mode_plans[mode]}
        )
        for tag in tag_names:
            pieces = [
                (p.mode_plans[mode][tag], offsets[i], edge_offsets[i])
                for i, p in enumerate(member_plans)
                if tag in p.mode_plans[mode]
            ]
            min_tiles = 0
            if edge_bucket > 0:
                ept = pieces[0][0].edges_per_tile
                real = sum(pl.total_edges for pl, _, _ in pieces)
                _, e_class = sched.size_class(0, real, 0, edge_bucket)
                min_tiles = -(-e_class // ept)
            per_tag[tag] = sched.concat_tile_plans(
                [pl for pl, _, _ in pieces],
                [off for _, off, _ in pieces],
                num_nodes=union.num_nodes,
                min_tiles=min_tiles,
                # Member edges occupy contiguous slices of the union's edge
                # array (members precede padding self-edges), so the member
                # graphs' cumulative edge counts relabel edge_ids into union
                # edge space — a request-time coefficient vector over the
                # union then scatters correctly through the assembled plan.
                edge_offsets=[eoff for _, _, eoff in pieces],
            )
        mode_plans[mode] = per_tag

    graph_fp = sched.graph_fingerprint(union)
    h = hashlib.blake2b(digest_size=16)
    h.update(graph_fp.encode())
    h.update(f"\x00assembled:{edge_bucket}".encode())
    for p in member_plans:
        h.update(b"\x00")
        h.update(p.fingerprint.encode())
    return ExecutionPlan(
        fingerprint=h.hexdigest(),
        graph_fp=graph_fp,
        num_nodes=union.num_nodes,
        num_edges=union.num_edges,
        cfg=cfg,
        precision_tags=tags,
        node_groups=groups,
        mode_plans=mode_plans,
    )


# ---------------------------------------------------------------------------
# Partition-aware planning: one ExecutionPlan per edge-balanced shard
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class ShardPlan:
    """One shard's compiled slice of a ``ShardedExecutionPlan``.

    ``plan`` is a full ExecutionPlan over the shard's *local* subgraph
    (owned rows first, halo sources appended — see
    ``graphs.partition.shard_subgraph``), so every property of the single-graph
    plan (hashability, persistence, bitwise-valid reuse) holds per shard.
    ``fingerprint`` is the global identity — hash(structure, partition
    boundaries, shard index, planner config) via
    ``scheduler.shard_plan_fingerprint`` — and is what the serving layer keys
    its per-shard LRU on.
    """

    fingerprint: str
    shard: ShardSubgraph
    plan: ExecutionPlan  # over shard.graph, in local index space

    def __hash__(self) -> int:
        return hash(self.fingerprint)

    def __eq__(self, other) -> bool:
        return isinstance(other, ShardPlan) and other.fingerprint == self.fingerprint

    @property
    def num_owned(self) -> int:
        return self.shard.num_owned

    @property
    def halo_size(self) -> int:
        return int(self.shard.halo.size)

    @property
    def num_edges(self) -> int:
        return self.shard.num_edges


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedExecutionPlan:
    """A partitioned graph's execution plan: one ShardPlan per shard.

    The distributed analogue of ``ExecutionPlan``: Degree-Quant tags are
    computed once on the global graph (a node's precision must not depend on
    which shard owns it), aggregation coefficients likewise (halo sources need
    their global degree), and each shard gets its own edge-tile plan over its
    local subgraph plus a precomputed halo gather map. Pure host-side and
    hashable by fingerprint, so the serving layer caches it — and each member
    ShardPlan independently — exactly like the single-graph plan.
    """

    fingerprint: str
    graph_fp: str
    partition_fp: str
    partition: Partition
    num_nodes: int
    num_edges: int
    cfg: EngineConfig
    precision_tags: np.ndarray  # str[N] — global tags
    node_groups: Mapping[str, np.ndarray]  # tag -> global node ids
    shards: Tuple[ShardPlan, ...]

    def __hash__(self) -> int:
        return hash(self.fingerprint)

    def __eq__(self, other) -> bool:
        return (
            isinstance(other, ShardedExecutionPlan)
            and other.fingerprint == self.fingerprint
        )

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def modes(self) -> Tuple[str, ...]:
        return self.shards[0].plan.modes if self.shards else ()

    @property
    def halo_total(self) -> int:
        """Rows crossing the cut per layer — the halo-exchange volume metric."""
        return sum(s.halo_size for s in self.shards)

    @property
    def edge_balance(self) -> float:
        """max shard edges / ideal edges-per-shard (1.0 = perfectly balanced)."""
        if not self.shards or self.num_edges == 0:
            return 1.0
        ideal = self.num_edges / self.num_shards
        return max(s.num_edges for s in self.shards) / ideal


def shard_plan_key(
    g: Graph,
    part: Partition,
    k: int,
    cfg: EngineConfig,
    *,
    modes: Sequence[str],
    precision_tags: np.ndarray,
) -> str:
    """The fingerprint ``compile_shard_plan`` would stamp on shard ``k``.

    Separated out so a serving cache can probe its per-shard LRU *before*
    deciding which shards actually need the planner.
    """
    tag_part = "tags:" + hashlib.blake2b(
        np.asarray(precision_tags, dtype="U8").tobytes(), digest_size=16
    ).hexdigest()
    return sched.shard_plan_fingerprint(
        g,
        part,
        k,
        repr(cfg),
        *sorted(dict.fromkeys(modes)),
        tag_part,
    )


def compile_shard_plan(
    g: Graph,
    part: Partition,
    k: int,
    cfg: Optional[EngineConfig] = None,
    *,
    modes: Sequence[str] = ("sum",),
    precision_tags: Optional[np.ndarray] = None,
    mode_coeffs: Optional[Mapping[str, np.ndarray]] = None,
) -> ShardPlan:
    """Compile shard ``k`` of a partitioned graph independently.

    ``precision_tags``/``mode_coeffs`` are *global* (length N / E); pass them
    when compiling several shards so tagging and coefficient work runs once —
    omitted, they are derived here (correct, just repeated per shard).
    The returned ShardPlan is exactly what ``compile_sharded_plans`` would
    have produced for this shard, so a serving cache can mix shards compiled
    together and separately.
    """
    cfg = cfg if cfg is not None else EngineConfig()
    if precision_tags is None:
        precision_tags = engine_precision_tags(g, cfg)
    tags = np.asarray(precision_tags)
    if tags.shape != (g.num_nodes,):
        raise ValueError(f"precision_tags must be [{g.num_nodes}], got {tags.shape}")
    if mode_coeffs is None:
        mode_coeffs = {m: aggregation_coefficients(g, m) for m in dict.fromkeys(modes)}
    sub = shard_subgraph(g, part, k)
    local_coeffs = {
        m: sub.slice_edges(np.asarray(c)) for m, c in mode_coeffs.items()
    }
    local_tags = tags[sub.local_ids]
    plan = compile_plans(
        sub.graph,
        cfg,
        modes=modes,
        precision_tags=local_tags,
        coeffs=local_coeffs,
    )
    fp = shard_plan_key(g, part, k, cfg, modes=modes, precision_tags=tags)
    return ShardPlan(fingerprint=fp, shard=sub, plan=plan)


def compile_sharded_plans(
    g: Graph,
    cfg: Optional[EngineConfig] = None,
    *,
    num_shards: Optional[int] = None,
    partition: Optional[Partition] = None,
    partitioner: str = "edges",
    modes: Sequence[str] = ("sum",),
    precision_tags: Optional[np.ndarray] = None,
    shard_plans: Optional[Mapping[int, ShardPlan]] = None,
) -> ShardedExecutionPlan:
    """Partition-aware planning pipeline: Partition in, sharded plan out.

    Give either an explicit ``partition`` (validated against ``g``) or
    ``num_shards`` — then ``partitioner`` selects the algorithm ("edges" =
    contiguous edge-balanced cut, "mincut" = halo-minimizing multilevel
    refinement; see ``graphs.partition.make_partition``). The partitioner
    identity is folded into ``partition_fp`` so plans never collide across
    partitioners. Degree-Quant tags and per-mode coefficients are computed
    once globally, then each shard is compiled over its local subgraph.
    ``shard_plans`` supplies already-compiled shards by index (the serving
    layer's per-shard cache hits); only missing shards run the planner.
    """
    cfg = cfg if cfg is not None else EngineConfig()
    if partition is None:
        if num_shards is None:
            raise ValueError("pass either partition or num_shards")
        partition = make_partition(g, num_shards, partitioner)
    else:
        validate_partition(g, partition)
        if num_shards is not None and partition.num_shards != num_shards:
            raise ValueError(
                f"partition has {partition.num_shards} shards, asked for {num_shards}"
            )
    if precision_tags is None:
        tags = engine_precision_tags(g, cfg)
    else:
        tags = np.asarray(precision_tags)
        if tags.shape != (g.num_nodes,):
            raise ValueError(f"precision_tags must be [{g.num_nodes}], got {tags.shape}")
    shard_plans = shard_plans or {}
    mode_coeffs = None
    if any(k not in shard_plans for k in range(partition.num_shards)):
        # Global per-edge coefficient work runs once, and only when some
        # shard actually needs the planner (all-warm assembly skips it).
        mode_coeffs = {m: aggregation_coefficients(g, m) for m in dict.fromkeys(modes)}
    shards = tuple(
        shard_plans[k]
        if k in shard_plans
        else compile_shard_plan(
            g,
            partition,
            k,
            cfg,
            modes=modes,
            precision_tags=tags,
            mode_coeffs=mode_coeffs,
        )
        for k in range(partition.num_shards)
    )
    groups = {tag: np.nonzero(tags == tag)[0] for tag in np.unique(tags)}
    partition_fp = sched.partition_fingerprint(g, partition)
    h = hashlib.blake2b(digest_size=16)
    h.update(partition_fp.encode())
    for s in shards:
        h.update(b"\x00")
        h.update(s.fingerprint.encode())
    return ShardedExecutionPlan(
        fingerprint=h.hexdigest(),
        graph_fp=sched.graph_fingerprint(g),
        partition_fp=partition_fp,
        partition=partition,
        num_nodes=g.num_nodes,
        num_edges=g.num_edges,
        cfg=cfg,
        precision_tags=tags,
        node_groups=groups,
        shards=shards,
    )


class AmpleEngine:
    """Thin per-graph execution wrapper around an ``ExecutionPlan``.

    The engine owns only transient device-facing state (the weight-quant
    cache); all planning lives in the plan. Construct either way:

      * ``AmpleEngine(g, cfg)`` — compiles tags up front, tile plans lazily
        per aggregation mode (the historical behaviour), or
      * ``AmpleEngine(g, plan=plan)`` — reuses a cached ``compile_plans``
        artifact and skips the planner entirely.
    """

    def __init__(
        self,
        g: Graph,
        cfg: Optional[EngineConfig] = None,
        *,
        plan: Optional[ExecutionPlan] = None,
    ):
        if plan is not None:
            if plan.graph_fp != sched.graph_fingerprint(g):
                raise ValueError(
                    f"plan was compiled for a different graph structure "
                    f"({plan.num_nodes} nodes, {plan.num_edges} edges vs "
                    f"{g.num_nodes}, {g.num_edges}; fingerprints differ)"
                )
            if cfg is not None and cfg != plan.cfg:
                raise ValueError("cfg disagrees with plan.cfg; pass one or the other")
            cfg = plan.cfg
        else:
            cfg = cfg if cfg is not None else EngineConfig()
            plan = compile_plans(g, cfg, modes=())
        self.graph = g
        self.cfg = cfg
        self.plan = plan
        self.precision_tags = plan.precision_tags
        self.node_groups: Dict[str, np.ndarray] = dict(plan.node_groups)
        self._plans: Dict[str, Mapping[str, sched.EdgeTilePlan]] = dict(plan.mode_plans)
        self._init_runtime_state()

    _WQ_CACHE_CAP = 64  # weights per engine; LRU-evicted beyond this

    def _init_runtime_state(self) -> None:
        """Transient device-facing caches — shared with ShardedAmpleEngine."""
        # id(w) -> (w, w_q, qp). The weight itself is held alongside its
        # quantized copy: a cache keyed on id() alone is unsound once the
        # original is garbage collected (CPython recycles ids), so the strong
        # ref both pins the id and lets us verify the hit is really for w.
        # Bounded LRU: a loop feeding ever-fresh weight arrays (training)
        # must not grow engine memory without limit.
        self._wq_cache: "OrderedDict[int, tuple]" = OrderedDict()
        # Static per-plan quantization state (serving): to_device_plan uploads
        # and activation scale/zero-points are calibrated once per (plan,
        # call-site) and reused on warm requests — see begin_forward().
        self._dplan_cache: Dict[str, Dict] = {}
        self._act_qp: Dict[tuple, QuantParams] = {}
        self._forward_active = False
        self._agg_slot = 0
        self._fte_slot = 0
        # (plan, schedule) pairs for the out-of-core path, keyed on
        # (mode, tag, chunk_rows, reorder, packing) — per-plan-static like
        # dplans. The plan entry is the one the stream executes: the packed
        # variant when packing is on, the compiled plan otherwise.
        self._chunk_schedules: Dict[tuple, tuple] = {}
        # Device copies of per-tile plan arrays for the streamed executor,
        # keyed like _chunk_schedules: a warm streamed request re-uploads
        # zero plan bytes (the instruction stream is plan-static).
        self._stream_tiles: Dict[tuple, object] = {}
        # (src, dst) node ids per edge — structural, cached for edge_softmax.
        self._edge_endpoints: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None
        # Modes whose plans were verified to carry live edge ids (the check
        # scans the tile arrays once; results are plan-static).
        self._eids_checked: set = set()

    # ------------------------------------------------- static quant state
    def begin_forward(self) -> None:
        """Mark the start of one model forward pass over this engine.

        Activation quantization parameters (int8 scale/zero-point for the AGE
        gather stream and the FTE int8 matmul) are keyed by call-site slot
        within a forward: the first forward calibrates them from its
        activations and later forwards reuse that static state — warm plan-
        cache hits skip ``compute_scale_zp`` entirely, and repeat requests
        with identical features are bitwise-identical to the cold request.
        Callers that never invoke this (direct engine use) keep the historical
        per-call dynamic calibration.
        """
        self._forward_active = True
        self._agg_slot = 0
        self._fte_slot = 0

    def _activation_qp(
        self,
        values_fn: Optional[Callable[[], jnp.ndarray]],
        kind: str,
        *,
        make_qp: Optional[Callable[[], QuantParams]] = None,
    ) -> QuantParams:
        """Scale/zp for one quantized call site (lazy: warm slots skip the calc).

        ``make_qp`` overrides the cold calibration source — the streamed
        paths pass a host-side factory (bitwise-equal to the device
        reduction) so the SAME slot protocol serves dense and streamed
        forwards; a warm slot cached by either path feeds both.
        """
        calibrate = (
            make_qp
            if make_qp is not None
            else lambda: compute_scale_zp(values_fn(), symmetric=True)
        )
        if not self._forward_active:
            return calibrate()
        if kind == "agg":
            slot = ("agg", self._agg_slot)
            self._agg_slot += 1
        else:
            slot = ("fte", self._fte_slot)
            self._fte_slot += 1
        if slot not in self._act_qp:
            qp = calibrate()
            if isinstance(qp.scale, jax.core.Tracer):
                # Under jit/grad tracing (training) the calibration is part of
                # the traced computation — caching it would leak tracers, so
                # stay dynamic and leave the slot empty for eager serving.
                return qp
            self._act_qp[slot] = qp
        return self._act_qp[slot]

    def _device_plans(
        self,
        mode: str,
        plans: Mapping[str, sched.EdgeTilePlan],
        *,
        edge_ids: bool = False,
    ) -> Dict:
        """Cached device uploads of one mode's tile plans.

        ``edge_ids`` uploads the runtime-coefficient indirection map too —
        it is as large as ``gather_idx`` and static-coeff modes never read
        it, so it rides along only on first runtime-coefficient use (a
        cached entry without it is upgraded in place).
        """
        cached = self._dplan_cache.get(mode)
        if cached is not None and (
            not edge_ids
            or all(d.edge_ids is not None for d in cached.values())
        ):
            return cached
        dplans = {
            tag: to_device_plan(p, with_edge_ids=edge_ids)
            for tag, p in plans.items()
        }
        # Inside jit/grad tracing, array creation is staged into the trace
        # (DynamicJaxprTracer constants) — caching those would leak tracers
        # into later eager calls, so only concrete uploads are kept.
        if not any(
            isinstance(d.gather_idx, jax.core.Tracer) for d in dplans.values()
        ):
            self._dplan_cache[mode] = dplans
        return dplans

    def _require_edge_ids(self, mode: str, plans: Mapping[str, sched.EdgeTilePlan]) -> None:
        """Refuse runtime coefficients on plans without live edge ids.

        Plans persisted before the indirection existed load with every lane
        at -1 (structurally valid, statically servable); scattering through
        them would silently zero every coefficient — fail loudly instead.
        """
        if mode in self._eids_checked:
            return
        for tag, p in plans.items():
            # Every real edge must own exactly one live lane — a partial
            # count means some member of an assembled union was loaded from
            # a pre-indirection file (its lanes sit at -1) and would be
            # silently zeroed by the scatter.
            if int((p.edge_ids >= 0).sum()) != p.total_edges:
                raise ValueError(
                    f"plan for mode {mode!r} tag {tag!r} carries edge-id "
                    "indirection for only part of its edges (a member "
                    "persisted before runtime coefficients?); recompile the "
                    "plan to use edge_coeff / edge_softmax"
                )
        self._eids_checked.add(mode)

    # ---------------------------------------------------------------- plans
    def plans(self, mode: str) -> Mapping[str, sched.EdgeTilePlan]:
        if mode not in self._plans:  # lazy extension beyond the compiled modes
            self._plans[mode] = sched.build_mixed_precision_plans(
                self.graph,
                self.precision_tags,
                edges_per_tile=self.cfg.edges_per_tile,
                segments_per_tile=self.cfg.segments_per_tile,
                coeff=aggregation_coefficients(self.graph, mode),
            )
        return self._plans[mode]

    # ------------------------------------------------- out-of-core streaming
    def _stream_plan_schedule(self, mode: str, tag: str, sf):
        """(plan, schedule) the streamed path executes (per-plan-static).

        ``sf.packing`` swaps in the chunk-packed variant of the compiled
        plan (``scheduler.pack_tiles_by_chunk``, bitwise-equal outputs) with
        plan-order execution — packing already emitted tiles in chunk order,
        so the run-reordering pass has nothing left to sort. Unpacked plans
        keep the ``sf.reorder`` run permutation.
        """
        key = (mode, tag, sf.store.chunk_rows, sf.reorder, sf.packing)
        if key not in self._chunk_schedules:
            plan = self.plans(mode)[tag]
            if sf.packing:
                plan = sched.pack_tiles_by_chunk(plan, sf.store.chunk_rows)
                schedule = sched.build_chunk_schedule(
                    plan, sf.store.chunk_rows, reorder=False
                )
            else:
                schedule = sched.build_chunk_schedule(
                    plan, sf.store.chunk_rows, reorder=sf.reorder
                )
            self._chunk_schedules[key] = (plan, schedule)
        return self._chunk_schedules[key]

    def _chunk_schedule(self, mode: str, tag: str, sf):
        """Schedule cache for the streamed path (per-plan-static artifact)."""
        return self._stream_plan_schedule(mode, tag, sf)[1]

    def _stream_tiles_for(self, mode: str, tag: str, sf):
        """Device copies of one plan's per-tile arrays (plan-static).

        Built (and charged to ``instr_bytes``) once per (mode, tag, chunking)
        — warm streamed requests re-upload zero plan bytes; only feature
        chunks move.
        """
        from repro.memory.prefetcher import make_device_tile_stream

        key = (mode, tag, sf.store.chunk_rows, sf.reorder, sf.packing)
        if key not in self._stream_tiles:
            plan, schedule = self._stream_plan_schedule(mode, tag, sf)
            ts = make_device_tile_stream(plan, schedule)
            self._stream_tiles[key] = ts
            sf.stats.instr_bytes += ts.nbytes  # the cold upload, charged once
        return self._stream_tiles[key]

    def _aggregate_streamed(self, sf, mode: str) -> jnp.ndarray:
        from repro.memory.prefetcher import aggregate_streamed

        if sf.store.num_rows != self.graph.num_nodes:
            raise ValueError(
                f"feature store has {sf.store.num_rows} rows but graph has "
                f"{self.graph.num_nodes} nodes"
            )
        pairs = {
            tag: self._stream_plan_schedule(mode, tag, sf)
            for tag in self.plans(mode)
        }
        plans = {tag: p for tag, (p, _) in pairs.items()}
        schedules = {tag: s for tag, (_, s) in pairs.items()}
        tiles = {tag: self._stream_tiles_for(mode, tag, sf) for tag in plans}
        qp = None
        if self.cfg.mixed_precision and "int8" in plans:
            qp = self._activation_qp(None, "agg", make_qp=sf.agg_qp)
        with otrace.get_recorder().span(
            f"layer:aggregate:{mode}", cat="engine",
            trace_id=getattr(sf, "trace_id", ""),
        ):
            return aggregate_streamed(
                sf,
                plans,
                schedules,
                num_nodes=self.graph.num_nodes,
                mixed=self.cfg.mixed_precision,
                qp=qp,
                tiles=tiles,
            )

    def _transform_streamed(
        self,
        sf,
        w: jnp.ndarray,
        b: Optional[jnp.ndarray],
        activation: Optional[Callable[[jnp.ndarray], jnp.ndarray]],
    ) -> jnp.ndarray:
        from repro.memory.prefetcher import _host_fte_qp, transform_streamed

        if sf.store.num_rows != self.graph.num_nodes:
            raise ValueError(
                f"feature store has {sf.store.num_rows} rows but graph has "
                f"{self.graph.num_nodes} nodes"
            )
        if not self.cfg.mixed_precision:
            # A float-policy FTE over the full matrix cannot be row-blocked
            # bitwise-identically (f32 matmul blocking reassociates), so the
            # store is materialized — loud in telemetry, never silent.
            sf.stats.fallbacks += 1
            sf.stats.fallback_bytes += sf.nbytes
            return transform_dense(jnp.asarray(sf.store.dense()), w, b, activation)
        w_q, w_qp, _ = self._weight_q(w)
        a_qp = None
        ids = self.node_groups.get("int8")
        if self._forward_active and ids is not None and ids.size:
            a_qp = self._activation_qp(
                None, "fte", make_qp=lambda: _host_fte_qp(sf.store.amax_rows(ids))
            )
        return transform_streamed(
            sf, self.node_groups, w, b, activation,
            w_q=w_q, w_qp=w_qp, a_qp=a_qp,
        )

    # ----------------------------------------------------------------- AGE
    def aggregate(
        self,
        x: jnp.ndarray,
        *,
        mode: str = "sum",
        edge_coeff: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        """Event-driven mixed-precision aggregation of node embeddings.

        ``x`` may be a ``memory.StreamedFeatures`` handle instead of a dense
        matrix: aggregation then runs chunk-streamed through the prefetcher
        under its feature budget, bitwise-identical to the dense path.

        ``edge_coeff`` is a runtime per-edge coefficient vector (f32[E] in
        this graph's edge space), scattered into tile layout through the
        plan's ``edge_ids`` map and multiplied with the static coefficients
        — the GAT attention path. The plan itself stays structure-keyed, so
        serving caches are untouched by per-request coefficient changes.

        Multi-head: ``edge_coeff`` f32[E, H] with ``x`` f32[N, H, dh]
        aggregates all heads in one tile scan (each head's column bitwise-
        equal to its solo 1-D run on the jnp path).
        """
        if isinstance(x, _streamed_features_type()):
            if edge_coeff is not None:
                raise ValueError(
                    "runtime edge coefficients require dense embeddings; the "
                    "streamed aggregation path serves static-coefficient "
                    "plans only (attention models stream through transform())"
                )
            return self._aggregate_streamed(x, mode)
        plans = self.plans(mode)
        if edge_coeff is not None:
            edge_coeff = jnp.asarray(edge_coeff, jnp.float32)
            e = self.graph.num_edges
            if not (
                edge_coeff.shape == (e,)
                or (edge_coeff.ndim == 2 and edge_coeff.shape[0] == e)
            ):
                raise ValueError(
                    f"edge_coeff must be [{e}] or [{e}, H], got "
                    f"{tuple(edge_coeff.shape)}"
                )
            if edge_coeff.ndim == 2 and (
                x.ndim != 3 or x.shape[1] != edge_coeff.shape[1]
            ):
                raise ValueError(
                    f"multi-head edge_coeff {tuple(edge_coeff.shape)} needs "
                    f"x shaped [N, {edge_coeff.shape[1]}, dh], got "
                    f"{tuple(x.shape)}"
                )
            self._require_edge_ids(mode, plans)
        dplans = self._device_plans(mode, plans, edge_ids=edge_coeff is not None)
        if self.cfg.mixed_precision:
            qp = self._activation_qp(lambda: x, "agg") if "int8" in plans else None
            return aggregate_mixed_precision(
                x,
                plans,
                num_nodes=self.graph.num_nodes,
                use_kernel=self.cfg.use_kernel,
                qp=qp,
                device_plans=dplans,
                edge_coeff=edge_coeff,
            )
        p = plans["float"]
        return aggregate_edge_tiles(
            x,
            dplans["float"],
            num_nodes=self.graph.num_nodes,
            segments_per_tile=p.segments_per_tile,
            use_kernel=self.cfg.use_kernel,
            edge_coeff=edge_coeff,
        )

    # ------------------------------------------------ runtime coefficients
    def edge_endpoints(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(src, dst) node id per edge, int32[E] each — cached structural
        arrays (dst follows from the CSR row layout)."""
        if self._edge_endpoints is None:
            g = self.graph
            dst = np.repeat(np.arange(g.num_nodes, dtype=np.int64), g.degrees)
            self._edge_endpoints = (
                jnp.asarray(g.indices, jnp.int32),
                jnp.asarray(dst, jnp.int32),
            )
        return self._edge_endpoints

    def edge_softmax(
        self, scores: jnp.ndarray, *, mode: str = "runtime"
    ) -> jnp.ndarray:
        """Destination-segment softmax of per-edge scores: f32[E(, H)].

        Runs over the same event-driven tiles as aggregation (per precision
        group, covering disjoint destination sets): a segment-max pass
        scatter-maxes tile partials into per-node maxima (the numerically
        stable shift), scores are exp-shifted in edge space, and a
        segment-sum pass accumulates the denominators through the same
        partial-response scatter-add. Nodes with no in-edges in the plan
        (size-class padding nodes) get max 0 / denominator 1, so the result
        is finite everywhere.

        ``scores`` may be f32[E, H]: every head shares one pair of tile
        scans and ONE destination-endpoint gather (``node_max[dst]`` /
        ``denom[dst]`` broadcast over the head axis), where the per-head
        loop paid both H times. Each head's column is bitwise-equal to its
        solo 1-D call.
        """
        scores = jnp.asarray(scores, jnp.float32)
        e = self.graph.num_edges
        if not (
            scores.shape == (e,)
            or (scores.ndim == 2 and scores.shape[0] == e)
        ):
            raise ValueError(
                f"scores must be [{e}] or [{e}, H], got "
                f"{tuple(scores.shape)}"
            )
        plans = self.plans(mode)
        self._require_edge_ids(mode, plans)
        dplans = self._device_plans(mode, plans, edge_ids=True)
        n = self.graph.num_nodes
        node_max = jnp.full((n,) + scores.shape[1:], -jnp.inf, jnp.float32)
        for tag, p in plans.items():
            node_max = jnp.maximum(
                node_max,
                segment_max_edge_tiles(
                    scores,
                    dplans[tag],
                    num_nodes=n,
                    segments_per_tile=p.segments_per_tile,
                ),
            )
        node_max = jnp.where(jnp.isfinite(node_max), node_max, 0.0)
        _, dst = self.edge_endpoints()
        # One structural gather per pass, shared by all heads.
        ex = jnp.exp(scores - node_max[dst])
        denom = jnp.zeros((n,) + scores.shape[1:], jnp.float32)
        for tag, p in plans.items():
            denom = denom + edge_segment_sum_tiles(
                ex,
                dplans[tag],
                num_nodes=n,
                segments_per_tile=p.segments_per_tile,
            )
        denom = jnp.where(denom > 0, denom, 1.0)
        return ex / denom[dst]

    def attention_aggregate(
        self,
        scores: jnp.ndarray,
        z: jnp.ndarray,
        *,
        mode: str = "runtime",
        leaky_slope: float = 0.2,
    ) -> jnp.ndarray:
        """One GAT layer's attention: softmax(LeakyReLU(scores)) aggregate.

        ``scores`` are the RAW per-edge logits f32[E, H] (pre-activation);
        ``z`` the head-stacked projected embeddings f32[N, H, dh]. Returns
        f32[N, H, dh].

        With ``use_kernel`` off this decomposes into the vectorized jnp
        passes (``edge_softmax`` + ``aggregate`` on the [E, H] layout — the
        always-on oracle). With ``use_kernel`` on, each precision group runs
        the fused Pallas kernel: LeakyReLU → tile-local segment-max → exp →
        segment-sum → weighted aggregate in ONE tile scan, combined across
        tiles by a flash-attention-style log-sum-exp rescale at the
        partial-response scatter. Precision groups cover disjoint
        destination nodes, so per-group softmax is exact; the fused path
        matches the oracle to float tolerance (tile-grouped summation
        re-associates), not bitwise.
        """
        if isinstance(z, _streamed_features_type()):
            raise ValueError(
                "attention requires dense embeddings; streamed features "
                "cannot carry the per-edge softmax (compute z densely or "
                "lift the feature budget)"
            )
        scores = jnp.asarray(scores, jnp.float32)
        z = jnp.asarray(z, jnp.float32)
        e, n = self.graph.num_edges, self.graph.num_nodes
        if scores.ndim != 2 or scores.shape[0] != e:
            raise ValueError(
                f"scores must be [{e}, H], got {tuple(scores.shape)}"
            )
        h = scores.shape[1]
        if z.ndim != 3 or z.shape[0] != n or z.shape[1] != h:
            raise ValueError(
                f"z must be [{n}, {h}, dh], got {tuple(z.shape)}"
            )
        if not self.cfg.use_kernel:
            act = jax.nn.leaky_relu(scores, leaky_slope)
            alpha = self.edge_softmax(act, mode=mode)
            return self.aggregate(z, mode=mode, edge_coeff=alpha)

        from repro.kernels.segment_agg import attn_ops

        plans = self.plans(mode)
        self._require_edge_ids(mode, plans)
        dplans = self._device_plans(mode, plans, edge_ids=True)
        qp = None
        if self.cfg.mixed_precision and "int8" in plans:
            qp = self._activation_qp(lambda: z, "agg")
        out = jnp.zeros_like(z)
        for tag, p in plans.items():
            x = z
            if tag == "int8" and self.cfg.mixed_precision:
                x = dequantize(quantize(z, qp), qp)
            dp = dplans[tag]
            sc_t = tile_edge_coeff(dp, scores, fill=-jnp.inf)
            out = out + attn_ops.attend_tiles(
                x,
                dp.gather_idx,
                sc_t,
                dp.coeff,
                dp.seg_ids,
                dp.out_node,
                num_nodes=n,
                segments_per_tile=p.segments_per_tile,
                leaky_slope=leaky_slope,
            )
        return out

    # ----------------------------------------------------------------- FTE
    def _weight_q(self, w: jnp.ndarray):
        """Per-weight quantization cache → (w_q, w_qp, w_packed).

        ``w_packed`` is the load-time Marlin-style repack of ``w_q`` into the
        Pallas matmul's native tile order — built once per weight, only when
        the engine routes the FTE through the kernel (the jnp oracle never
        reads it), so every warm transform hands the kernel its preferred
        layout with zero per-call transpose.
        """
        key = id(w)
        entry = self._wq_cache.get(key)
        if entry is None or entry[0] is not w:
            w_q, w_qp = quantize_per_channel(w, axis=-1)
            packed = None
            if self.cfg.use_kernel:
                from repro.kernels.quant_matmul import ops as qm_ops

                packed = qm_ops.repack_weight(w_q)
            entry = (w, w_q, w_qp, packed)
            self._wq_cache[key] = entry
            while len(self._wq_cache) > self._WQ_CACHE_CAP:
                self._wq_cache.popitem(last=False)
        else:
            self._wq_cache.move_to_end(key)
        return entry[1], entry[2], entry[3]

    def transform(
        self,
        h: jnp.ndarray,
        w: jnp.ndarray,
        b: Optional[jnp.ndarray] = None,
        activation: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
    ) -> jnp.ndarray:
        """Mixed-precision transformation of aggregated embeddings.

        Accepts a ``memory.StreamedFeatures`` handle for ``h``: the int8
        group then streams chunk-blocked (1-byte rows, exact int32 matmul)
        and the float-protected block is gathered once — bitwise-identical
        to the dense mixed path (GraphSAGE's φ over stored features).
        """
        if isinstance(h, _streamed_features_type()):
            return self._transform_streamed(h, w, b, activation)
        if not self.cfg.mixed_precision:
            return transform_dense(h, w, b, activation)
        w_q, w_qp, w_packed = self._weight_q(w)
        a_qp = None
        ids = self.node_groups.get("int8")
        if self._forward_active and ids is not None and ids.size:
            a_qp = self._activation_qp(
                lambda: h[jnp.asarray(ids, jnp.int32)], "fte"
            )
        return transform_mixed_precision(
            h,
            self.node_groups,
            w,
            b,
            activation,
            w_q=w_q,
            w_qp=w_qp,
            a_qp=a_qp,
            use_kernel=self.cfg.use_kernel,
            w_packed=w_packed,
        )

    # ------------------------------------------------------------- metrics
    def occupancy_report(self) -> Dict[str, float]:
        """Lane economics vs the double-buffered baseline (same graph)."""
        plan = sched.build_edge_tile_plan(
            self.graph, edges_per_tile=self.cfg.edges_per_tile
        )
        padded = sched.build_padded_plan(self.graph, batch_size=64)
        return {
            "event_driven_lane_occupancy": plan.lane_occupancy,
            "double_buffer_pipeline_gap_ratio": padded.pipeline_gap_ratio,
            "float_node_ratio": float(
                (self.precision_tags == "float").mean() if self.graph.num_nodes else 0
            ),
        }
