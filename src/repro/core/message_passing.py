"""The AMPLE engine facade: graph in → event-driven mixed-precision layer out.

``AmpleEngine`` is the software equivalent of the accelerator's top level
(Figure 1): it owns the planner outputs (NID programming), the precision tags
(Degree-Quant), the aggregation coefficients per model (AGE configuration) and
the weight quantization cache (Weight Bank), and exposes a single
``layer(x, phi/gamma weights)`` entry point the GNN models call per layer.

Message-passing semantics follow Eq. 1:
    x_i' = γ(x_i, A_{j∈N(i)} φ(x_i, x_j, e_ij))
with φ folded into per-edge coefficients for GCN/GIN (φ = c_ij · x_j) and a
dense pre-projection for GraphSAGE (φ = σ(W3 x_j + b)).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as sched
from repro.core.aggregation import (
    aggregate_edge_tiles,
    aggregate_mixed_precision,
    to_device_plan,
)
from repro.core.degree_quant import DegreeQuantConfig, inference_precision_tags
from repro.core.quantization import QuantParams, compute_scale_zp, quantize_per_channel
from repro.core.transformation import (
    transform_dense,
    transform_int8,
    transform_mixed_precision,
)
from repro.graphs.csr import Graph, gcn_norm_coeffs

__all__ = ["EngineConfig", "AmpleEngine"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    edges_per_tile: int = 256
    segments_per_tile: Optional[int] = None
    mixed_precision: bool = True
    use_kernel: bool = False  # route through Pallas kernels (interpret on CPU)
    dq: DegreeQuantConfig = dataclasses.field(default_factory=DegreeQuantConfig)


class AmpleEngine:
    """Per-graph execution engine (plans are built once, reused every layer).

    Aggregation coefficient modes:
      * "sum"  — coeff 1 (GIN)
      * "mean" — coeff 1/deg(i) (GraphSAGE)
      * "gcn"  — coeff 1/√(d̂_i d̂_j) (GCN; self-loops must already be present)
    """

    def __init__(self, g: Graph, cfg: EngineConfig = EngineConfig()):
        self.graph = g
        self.cfg = cfg
        if cfg.mixed_precision:
            self.precision_tags = inference_precision_tags(g, cfg.dq)
        else:
            self.precision_tags = np.full(g.num_nodes, "float", dtype=object).astype(
                str
            )
        self.node_groups: Dict[str, np.ndarray] = {
            tag: np.nonzero(self.precision_tags == tag)[0]
            for tag in np.unique(self.precision_tags)
        }
        self._plans: Dict[str, Dict[str, sched.EdgeTilePlan]] = {}
        self._wq_cache: Dict[int, tuple] = {}

    # ---------------------------------------------------------------- plans
    def _coeff(self, mode: str) -> np.ndarray:
        g = self.graph
        if mode == "sum":
            return np.ones(g.num_edges, np.float32)
        if mode == "mean":
            deg = np.maximum(g.degrees, 1).astype(np.float32)
            return (1.0 / np.repeat(deg, g.degrees)).astype(np.float32)
        if mode == "gcn":
            return gcn_norm_coeffs(g)
        raise ValueError(f"unknown aggregation mode {mode!r}")

    def plans(self, mode: str) -> Dict[str, sched.EdgeTilePlan]:
        if mode not in self._plans:
            self._plans[mode] = sched.build_mixed_precision_plans(
                self.graph,
                self.precision_tags,
                edges_per_tile=self.cfg.edges_per_tile,
                segments_per_tile=self.cfg.segments_per_tile,
                coeff=self._coeff(mode),
            )
        return self._plans[mode]

    # ----------------------------------------------------------------- AGE
    def aggregate(self, x: jnp.ndarray, *, mode: str = "sum") -> jnp.ndarray:
        """Event-driven mixed-precision aggregation of node embeddings."""
        plans = self.plans(mode)
        if self.cfg.mixed_precision:
            return aggregate_mixed_precision(
                x,
                plans,
                num_nodes=self.graph.num_nodes,
                use_kernel=self.cfg.use_kernel,
            )
        p = plans["float"]
        return aggregate_edge_tiles(
            x,
            to_device_plan(p),
            num_nodes=self.graph.num_nodes,
            segments_per_tile=p.segments_per_tile,
            use_kernel=self.cfg.use_kernel,
        )

    # ----------------------------------------------------------------- FTE
    def _weight_q(self, w: jnp.ndarray):
        key = id(w)
        if key not in self._wq_cache:
            self._wq_cache[key] = quantize_per_channel(w, axis=-1)
        return self._wq_cache[key]

    def transform(
        self,
        h: jnp.ndarray,
        w: jnp.ndarray,
        b: Optional[jnp.ndarray] = None,
        activation: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
    ) -> jnp.ndarray:
        """Mixed-precision transformation of aggregated embeddings."""
        if not self.cfg.mixed_precision:
            return transform_dense(h, w, b, activation)
        w_q, w_qp = self._weight_q(w)
        return transform_mixed_precision(
            h,
            self.node_groups,
            w,
            b,
            activation,
            w_q=w_q,
            w_qp=w_qp,
            use_kernel=self.cfg.use_kernel,
        )

    # ------------------------------------------------------------- metrics
    def occupancy_report(self) -> Dict[str, float]:
        """Lane economics vs the double-buffered baseline (same graph)."""
        plan = sched.build_edge_tile_plan(
            self.graph, edges_per_tile=self.cfg.edges_per_tile
        )
        padded = sched.build_padded_plan(self.graph, batch_size=64)
        return {
            "event_driven_lane_occupancy": plan.lane_occupancy,
            "double_buffer_pipeline_gap_ratio": padded.pipeline_gap_ratio,
            "float_node_ratio": float(
                (self.precision_tags == "float").mean() if self.graph.num_nodes else 0
            ),
        }
