"""Aggregation Engine (AGE) — device-side execution of the schedules.

Three execution paths mirror the paper's comparison:

* ``aggregate_edge_tiles``  — event-driven path (AMPLE): ``lax.scan`` over the
  planner's dense edge tiles; each step gathers a tile of neighbour embeddings
  (HBM→VMEM stream in the Pallas version), reduces by local segment, and
  scatter-adds partial results (partial-response combining). Compute ∝ E.
* ``aggregate_bucket_plan`` — degree-bucketed padding (≤2× waste); the only
  path supporting ``max`` aggregation.
* ``aggregate_padded_plan`` — HyGCN-style double-buffer baseline, one padded
  dense batch at a time; its wasted lanes are the pipeline gaps AMPLE removes.

All paths produce identical results (property-tested); they differ only in
lane economics, which the benchmarks measure.

The per-edge ``coeff`` folds the aggregation function into the plan:
sum → 1, mean → 1/deg, GCN → 1/√(d̂_i d̂_j). Invalid lanes carry coeff 0.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as sched
from repro.core.quantization import QuantParams, compute_scale_zp, dequantize, quantize

__all__ = [
    "DeviceTilePlan",
    "to_device_plan",
    "tile_edge_coeff",
    "aggregate_edge_tiles",
    "aggregate_bucket_plan",
    "aggregate_padded_plan",
    "aggregate_mixed_precision",
    "segment_max_edge_tiles",
    "edge_segment_sum_tiles",
    "dense_reference",
]


class DeviceTilePlan(NamedTuple):
    """jnp mirror of scheduler.EdgeTilePlan (leaves scanned over axis 0).

    ``edge_ids`` is None when the plan was uploaded without the runtime-
    coefficient indirection (static-coeff modes never read it, and the array
    is as large as ``gather_idx`` — engines upload it on first use instead).
    """

    gather_idx: jnp.ndarray  # int32[T, E]
    coeff: jnp.ndarray  # f32[T, E]
    seg_ids: jnp.ndarray  # int32[T, E]
    out_node: jnp.ndarray  # int32[T, S]
    edge_ids: Optional[jnp.ndarray]  # int32[T, E]; -1 on padding lanes


def to_device_plan(
    plan: sched.EdgeTilePlan, *, with_edge_ids: bool = True
) -> DeviceTilePlan:
    return DeviceTilePlan(
        gather_idx=jnp.asarray(plan.gather_idx, jnp.int32),
        coeff=jnp.asarray(plan.coeff, jnp.float32),
        seg_ids=jnp.asarray(plan.seg_ids, jnp.int32),
        out_node=jnp.asarray(plan.out_node, jnp.int32),
        edge_ids=(
            jnp.asarray(plan.edge_ids, jnp.int32) if with_edge_ids else None
        ),
    )


def tile_edge_coeff(
    dplan: DeviceTilePlan, edge_coeff: jnp.ndarray, *, fill: float = 0.0
) -> jnp.ndarray:
    """Scatter a per-edge runtime matrix into tile layout: f32/…[T, E(, H)].

    ``edge_coeff`` is indexed by graph edge position (the space
    ``EdgeTilePlan.edge_ids`` maps lanes into); padding lanes (edge id -1)
    read ``fill``. This is the runtime half of the coefficient indirection:
    the tile arrays stay structure-keyed while the values change per request.

    ``edge_coeff`` may carry trailing dims — ``[E, H]`` for per-head
    attention coefficients scatters every head in one gather, yielding the
    ``[T, lanes, H]`` tile layout the vectorized softmax/aggregate passes
    consume (the 1-D case is bitwise-unchanged).
    """
    if dplan.edge_ids is None:
        raise ValueError(
            "device plan was uploaded without edge_ids; rebuild it with "
            "to_device_plan(plan, with_edge_ids=True) to use runtime "
            "coefficients"
        )
    e = edge_coeff.shape[0]
    padded = jnp.concatenate(
        [edge_coeff, jnp.full((1,) + edge_coeff.shape[1:], fill, edge_coeff.dtype)]
    )
    idx = jnp.where(dplan.edge_ids < 0, e, dplan.edge_ids)
    return padded[idx]


@partial(jax.jit, static_argnames=("num_nodes", "segments_per_tile", "use_kernel"))
def aggregate_edge_tiles(
    x: jnp.ndarray,
    dplan: DeviceTilePlan,
    *,
    num_nodes: int,
    segments_per_tile: int,
    use_kernel: bool = False,
    edge_coeff: Optional[jnp.ndarray] = None,
    out_init: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Event-driven aggregation: scan tiles, segment-reduce, scatter-add.

    ``use_kernel`` routes the per-tile reduction through the Pallas AGE kernel
    (kernels/segment_agg); the default path is pure jnp and serves as its
    always-on oracle.

    ``edge_coeff`` supplies a runtime per-edge coefficient vector (f32[E] in
    graph edge space); it is scattered into tile layout through the plan's
    ``edge_ids`` and **multiplied** with the static coeff. Plans compiled in
    ``"runtime"`` mode carry static coeff 1 on every real lane, so the
    runtime vector takes effect verbatim there (``1.0 * c == c`` bitwise);
    padding lanes are 0 in both factors.

    Multi-head layout: ``edge_coeff`` f32[E, H] with ``x`` f32[N, H, dh]
    aggregates every head in ONE tile scan — per-head coefficients broadcast
    over the head's feature slice, and each head's lane/segment reduction
    order is identical to its solo 1-D run (bitwise per head on this path).

    ``out_init`` (f32[num_nodes, …]) seeds the scatter accumulator instead of
    zeros — the continuation hook of the split interior/boundary execution
    (``scheduler.split_plan_by_halo``): the boundary scan picks up exactly
    where the interior scan left off, so split == unsplit bitwise. jnp path
    only (the Pallas kernel owns its accumulator).
    """
    coeff = dplan.coeff
    if edge_coeff is not None:
        tc = tile_edge_coeff(dplan, edge_coeff)  # [T, E] or [T, E, H]
        coeff = coeff[..., None] * tc if tc.ndim == 3 else coeff * tc
    if use_kernel:
        if out_init is not None:
            raise ValueError(
                "out_init continuation is only supported on the jnp path; "
                "run the kernel path unsplit"
            )
        if coeff.ndim == 3:
            from repro.kernels.segment_agg import attn_ops

            return attn_ops.aggregate_tiles_mh(
                x,
                dplan.gather_idx,
                coeff,
                dplan.seg_ids,
                dplan.out_node,
                num_nodes=num_nodes,
                segments_per_tile=segments_per_tile,
            )
        from repro.kernels.segment_agg import ops as seg_ops

        if x.ndim == 3:
            # head-uniform coefficients: heads are just feature columns
            n, h, dh = x.shape
            flat = seg_ops.aggregate_tiles(
                x.reshape(n, h * dh),
                dplan.gather_idx,
                coeff,
                dplan.seg_ids,
                dplan.out_node,
                num_nodes=num_nodes,
                segments_per_tile=segments_per_tile,
            )
            return flat.reshape(num_nodes, h, dh)
        return seg_ops.aggregate_tiles(
            x,
            dplan.gather_idx,
            coeff,
            dplan.seg_ids,
            dplan.out_node,
            num_nodes=num_nodes,
            segments_per_tile=segments_per_tile,
        )

    if out_init is None:
        out = jnp.zeros((num_nodes + 1,) + x.shape[1:], x.dtype)
    else:
        # one scratch sentinel row appended; values carry over bitwise
        out = jnp.concatenate(
            [
                out_init.astype(x.dtype),
                jnp.zeros((1,) + x.shape[1:], x.dtype),
            ]
        )

    def body(out, tile):
        gather_idx, coeff, seg_ids, out_node = tile
        gathered = x[gather_idx]  # [E, D] or [E, H, dh]
        cf = coeff.reshape(coeff.shape + (1,) * (gathered.ndim - coeff.ndim))
        partial_sums = jax.ops.segment_sum(
            gathered * cf, seg_ids, num_segments=segments_per_tile
        )  # [S, …]
        out = out.at[out_node].add(partial_sums)
        return out, None

    out, _ = jax.lax.scan(
        body, out, (dplan.gather_idx, coeff, dplan.seg_ids, dplan.out_node)
    )
    return out[:num_nodes]


def aggregate_bucket_plan(
    x: jnp.ndarray,
    plan: sched.BucketPlan,
    *,
    op: str = "sum",
) -> jnp.ndarray:
    """Degree-bucketed aggregation. op ∈ {sum, mean, max}.

    mean/GCN normalisation is normally folded into coeff; ``op='mean'`` here
    divides by the true lane count instead (used by GraphSAGE whose mean is
    over the *messages*, after φ). ``max`` masks padding lanes to -inf.
    """
    n = plan.num_nodes
    d = x.shape[1]
    if op == "max":
        out = jnp.full((n + 1, d), -jnp.inf, x.dtype)
    else:
        out = jnp.zeros((n + 1, d), x.dtype)
    for b in plan.buckets:
        gi = jnp.asarray(b.gather_idx)  # [M, C]
        cf = jnp.asarray(b.coeff)  # [M, C]
        ids = jnp.asarray(b.node_ids, jnp.int32)
        gathered = x[gi]  # [M, C, D]
        if op == "max":
            masked = jnp.where(cf[..., None] != 0, gathered, -jnp.inf)
            red = jnp.max(masked, axis=1)
            out = out.at[ids].max(red)
        elif op == "mean":
            cnt = jnp.maximum((cf != 0).sum(axis=1, keepdims=True), 1)
            red = (gathered * (cf != 0)[..., None]).sum(axis=1) / cnt
            out = out.at[ids].add(red)
        else:
            red = (gathered * cf[..., None]).sum(axis=1)
            out = out.at[ids].add(red)
    out = out[:n]
    if op == "max":
        out = jnp.where(jnp.isfinite(out), out, 0.0)
    return out


def aggregate_padded_plan(x: jnp.ndarray, plan: sched.PaddedPlan) -> jnp.ndarray:
    """Double-buffer baseline: one padded batch at a time (distinct shapes per
    batch — exactly the recompile/stall economics of static batching)."""
    n = plan.num_nodes
    d = x.shape[1]
    out = jnp.zeros((n, d), x.dtype)
    for b in plan.batches:
        gi = jnp.asarray(b.gather_idx)
        cf = jnp.asarray(b.coeff)
        ids = jnp.asarray(b.node_ids, jnp.int32)
        red = (x[gi] * cf[..., None]).sum(axis=1)
        out = out.at[ids].set(red)
    return out


@partial(jax.jit, static_argnames=("num_nodes", "segments_per_tile"))
def segment_max_edge_tiles(
    scores: jnp.ndarray,
    dplan: DeviceTilePlan,
    *,
    num_nodes: int,
    segments_per_tile: int,
) -> jnp.ndarray:
    """Destination-segment max of a per-edge vector, over the event-driven
    tiles: f32[N] (−inf for nodes this plan gives no edges).

    The max-shift pass of a numerically stable segment softmax (GAT): scores
    are scattered into tile layout through ``edge_ids`` (padding lanes read
    −inf), reduced per segment, and combined across split tiles by
    scatter-max — the partial-response mechanism with max in place of add.

    ``scores`` may be f32[E, H]: all heads reduce in the same scan
    (→ f32[N, H]), each head's column bitwise-equal to its solo 1-D pass.
    """
    sc = tile_edge_coeff(dplan, scores, fill=-jnp.inf)
    out = jnp.full((num_nodes + 1,) + scores.shape[1:], -jnp.inf, scores.dtype)

    def body(out, tile):
        sc_t, seg_ids, out_node = tile
        partial_max = jax.ops.segment_max(
            sc_t, seg_ids, num_segments=segments_per_tile
        )
        out = out.at[out_node].max(partial_max)
        return out, None

    out, _ = jax.lax.scan(body, out, (sc, dplan.seg_ids, dplan.out_node))
    return out[:num_nodes]


@partial(jax.jit, static_argnames=("num_nodes", "segments_per_tile"))
def edge_segment_sum_tiles(
    values: jnp.ndarray,
    dplan: DeviceTilePlan,
    *,
    num_nodes: int,
    segments_per_tile: int,
) -> jnp.ndarray:
    """Destination-segment sum of a per-edge vector over the tiles: f32[N].

    The denominator pass of the segment softmax: exp-shifted scores scatter
    through ``edge_ids`` (padding lanes read 0) and accumulate exactly like
    the aggregation scan, so split nodes combine by the same partial-response
    scatter-add.

    ``values`` may be f32[E, H] (→ f32[N, H], one scan for all heads).
    """
    v = tile_edge_coeff(dplan, values, fill=0.0)
    out = jnp.zeros((num_nodes + 1,) + values.shape[1:], values.dtype)

    def body(out, tile):
        v_t, seg_ids, out_node = tile
        partial_sums = jax.ops.segment_sum(
            v_t, seg_ids, num_segments=segments_per_tile
        )
        out = out.at[out_node].add(partial_sums)
        return out, None

    out, _ = jax.lax.scan(body, out, (v, dplan.seg_ids, dplan.out_node))
    return out[:num_nodes]


def aggregate_mixed_precision(
    x: jnp.ndarray,
    plans: Dict[str, sched.EdgeTilePlan],
    *,
    num_nodes: int,
    use_kernel: bool = False,
    qp: Optional[QuantParams] = None,
    device_plans: Optional[Dict[str, DeviceTilePlan]] = None,
    edge_coeff: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Mixed-precision AGE: the float plan consumes fp32 embeddings; the int8
    plan consumes int8-quantized embeddings (4× lighter gather traffic — the
    bandwidth win the paper banks on), dequantized on-chip before accumulate.

    The two streams write disjoint node sets, so the combined output is just
    the sum of the two scatter targets.

    ``qp`` overrides the activation scale/zero-point (per-call min/max
    calibration otherwise) — the engine passes its per-plan static quant state
    here, and the sharded executor a globally calibrated qp so every shard
    quantizes identically. ``device_plans`` supplies already-uploaded
    ``DeviceTilePlan`` mirrors keyed like ``plans`` (host→device conversion is
    per-plan-static and cacheable). ``edge_coeff`` is the runtime per-edge
    coefficient vector (graph edge space) both precision streams scatter
    through their ``edge_ids`` maps — each plan covers a disjoint destination
    subset, so one vector feeds both. A 2-D ``edge_coeff`` (f32[E, H]) with
    ``x`` f32[N, H, dh] runs the multi-head layout through both streams.
    """
    device_plans = device_plans or {}

    def dplan(tag):
        return device_plans.get(tag) or to_device_plan(plans[tag])

    out = jnp.zeros((num_nodes,) + x.shape[1:], jnp.float32)
    if "float" in plans:
        p = plans["float"]
        out = out + aggregate_edge_tiles(
            x,
            dplan("float"),
            num_nodes=num_nodes,
            segments_per_tile=p.segments_per_tile,
            use_kernel=use_kernel,
            edge_coeff=edge_coeff,
        )
    if "int8" in plans:
        p = plans["int8"]
        if qp is None:
            qp = compute_scale_zp(x, symmetric=True)
        xq = quantize(x, qp)
        xdq = dequantize(xq, qp)  # on-chip dequant after int8 gather
        out = out + aggregate_edge_tiles(
            xdq,
            dplan("int8"),
            num_nodes=num_nodes,
            segments_per_tile=p.segments_per_tile,
            use_kernel=use_kernel,
            edge_coeff=edge_coeff,
        )
    for tag, p in plans.items():
        if tag not in ("float", "int8"):
            raise ValueError(f"unknown precision tag {tag!r}")
    return out


def dense_reference(x: jnp.ndarray, adjacency: np.ndarray) -> jnp.ndarray:
    """O(N²) oracle: A @ X with A[i,j] = coeff of edge j→i (tests only)."""
    return jnp.asarray(adjacency) @ x
