"""Benchmark harness — one function per paper table/figure + LM benches.

Prints ``name,us_per_call,derived`` CSV rows (derived column is
metric-specific, annotated per row). CPU wall-clock rows measure THIS
machine's jnp engine; accelerator rows come from the discrete-event simulator
(core/simulator.py) at the paper's 200 MHz operating point; paper-published
CPU/GPU baselines are carried as reference constants where a real Xeon/A6000
is unavailable.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Callable, List

import numpy as np

# Large-graph rows regenerate yelp/reddit-scale lognormal graphs; cache the
# structures on disk so repeat bench runs skip the dominant setup cost.
# Anchored to the repo root (same default as tests/conftest.py) so runs from
# any cwd share one cache.
os.environ.setdefault(
    "REPRO_DATASET_CACHE",
    os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        ".dataset-cache",
    ),
)

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _time(fn: Callable, *, reps: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


# ------------------------------------------------------- Table 4: DQ ratios
def table4_dq_ratios(quick: bool) -> None:
    """Degree-Quant protection ratios on the (synthetic) paper datasets."""
    from repro.core.degree_quant import DegreeQuantConfig, inference_precision_tags
    from repro.graphs.datasets import PAPER_DATASETS, make_dataset

    for name, spec in PAPER_DATASETS.items():
        n = min(spec.num_nodes, 50_000 if quick else 250_000)
        g = make_dataset(name, max_nodes=n, with_features=False)
        t0 = time.perf_counter()
        tags = inference_precision_tags(
            g, DegreeQuantConfig(float_ratio=spec.dq_float_ratio)
        )
        us = (time.perf_counter() - t0) * 1e6
        got = float((tags == "float").mean())
        emit(
            f"table4_dq_ratio_{name}", us,
            f"float_ratio={got:.4f};paper={spec.dq_float_ratio:.3f}",
        )


# ------------------------------------- Table 5: latency/throughput (GCN)
PAPER_CPU_MS = {"cora": 244.4, "citeseer": 244.3, "pubmed": 362.4,
                "flickr": 475.4, "reddit": 953.3, "yelp": 760.8}
PAPER_GPU_MS = {"cora": 7.2, "citeseer": 10.1, "pubmed": 4.8,
                "flickr": 14.5, "reddit": 171.0, "yelp": 110.9}
PAPER_AMPLE_MS = {"cora": 0.246, "citeseer": 0.294, "pubmed": 1.617,
                  "flickr": 7.227, "reddit": 24.6, "yelp": 57.5}


def table5_latency(quick: bool) -> None:
    from repro.core.simulator import simulate_dataset

    cap = 30_000 if quick else 120_000
    gains = []
    for name in PAPER_CPU_MS:
        t0 = time.perf_counter()
        rec = simulate_dataset(name, max_nodes=cap)
        us = (time.perf_counter() - t0) * 1e6
        gain_cpu = PAPER_CPU_MS[name] / rec["latency_ms"]
        gains.append(gain_cpu)
        emit(
            f"table5_ample_{name}", us,
            f"sim_ms={rec['latency_ms']:.3f};paper_ms={PAPER_AMPLE_MS[name]:.3f};"
            f"gain_vs_paper_cpu={gain_cpu:.0f}x;nodes_per_ms={rec['nodes_per_ms']:.0f}",
        )
    emit("table5_mean_cpu_gain", 0.0, f"mean_gain={np.mean(gains):.0f}x;paper=361x")


# ----------------------------- Figure 4: speedup across models × datasets
def figure4_speedup(quick: bool) -> None:
    """Event-driven vs double-buffered accelerator, per model family.

    GIN doubles the FTE work (2-layer MLP); GraphSAGE adds the φ projection
    before aggregation — Table 3 structure (handled in simulate_dataset via
    hidden dims).
    """
    from repro.core.simulator import SimConfig, simulate_dataset

    cap = 20_000 if quick else 90_000
    datasets = ["cora", "pubmed", "flickr"] if quick else list(PAPER_CPU_MS)
    for model in ["gcn", "gin", "sage"]:
        sp = []
        for name in datasets:
            ev = simulate_dataset(name, model=model, max_nodes=cap)
            db = simulate_dataset(
                name, model=model, max_nodes=cap, cfg=SimConfig(event_driven=False)
            )
            sp.append(db["latency_ms"] / ev["latency_ms"])
        emit(
            f"figure4_event_driven_speedup_{model}", 0.0,
            f"geomean_vs_double_buffer={float(np.exp(np.mean(np.log(sp)))):.2f}x;"
            f"datasets={len(sp)}",
        )


# ----------------------- engine wall-clock: scheduling paths on this CPU
def bench_engine_paths(quick: bool) -> None:
    import jax.numpy as jnp

    from repro.core import build_edge_tile_plan, build_padded_plan
    from repro.core.aggregation import (
        aggregate_edge_tiles,
        aggregate_padded_plan,
        to_device_plan,
    )
    from repro.graphs.datasets import make_dataset

    n = 3_000 if quick else 19_717
    g = make_dataset("pubmed", max_nodes=n, max_feature_dim=128)
    x = jnp.asarray(g.features)
    plan = build_edge_tile_plan(g, edges_per_tile=256)
    dplan = to_device_plan(plan)
    kw = dict(num_nodes=g.num_nodes, segments_per_tile=plan.segments_per_tile)

    us_ev = _time(lambda: aggregate_edge_tiles(x, dplan, **kw).block_until_ready())
    emit("engine_event_driven_agg", us_ev,
         f"occupancy={plan.lane_occupancy:.3f};edges={g.num_edges}")

    padded = build_padded_plan(g, batch_size=64)
    us_pad = _time(
        lambda: aggregate_padded_plan(x, padded).block_until_ready(), reps=1
    )
    emit("engine_double_buffer_agg", us_pad,
         f"gap_ratio={padded.pipeline_gap_ratio:.3f};speedup_ev={us_pad/us_ev:.2f}x")


def bench_mixed_precision(quick: bool) -> None:
    import jax.numpy as jnp

    from repro.core import AmpleEngine, EngineConfig
    from repro.graphs.datasets import make_dataset

    n = 2_000 if quick else 10_000
    g = make_dataset("cora", max_nodes=n, max_feature_dim=256)
    x = jnp.asarray(g.features)
    eng_fp = AmpleEngine(g, EngineConfig(mixed_precision=False))
    eng_mp = AmpleEngine(g, EngineConfig(mixed_precision=True))
    us_fp = _time(lambda: eng_fp.aggregate(x).block_until_ready())
    us_mp = _time(lambda: eng_mp.aggregate(x).block_until_ready())
    rep = eng_mp.occupancy_report()
    emit("engine_fp32_agg", us_fp, "precision=float32")
    emit("engine_mixed_agg", us_mp,
         f"float_ratio={rep['float_node_ratio']:.3f};gather_bytes_ratio=0.28")


# ------------------------------ gnn-serve: plan cache economics (serving)
def bench_gnn_serve(quick: bool) -> None:
    """Cold-plan vs cache-hit latency through GNNServeEngine, plus batched
    small-graph serving — the serving analogue of nodeslot recycling."""
    import jax

    from repro.configs.base import get_config
    from repro.graphs.datasets import make_dataset
    from repro.serve.gnn_engine import GNNRequest, GNNServeEngine

    cfg = get_config("ample-gcn", reduced=True)
    n = 1_000 if quick else 5_000
    g = make_dataset("cora", max_nodes=n, max_feature_dim=cfg.d_model, seed=0)
    eng = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))

    cold = eng.infer(g, g.features)  # pays planner + jit
    warm = eng.infer(g, g.features)  # plan-cache hit, compiled device call
    warm_us = _time(lambda: eng.infer(g, g.features), reps=3)
    emit(
        "gnn_serve_cold_plan", cold.plan_ms * 1e3,
        f"nodes={g.num_nodes};edges={g.num_edges};cache_hit={cold.cache_hit}",
    )
    emit(
        "gnn_serve_cache_hit", warm_us,
        f"plan_ms={warm.plan_ms:.3f};speedup_vs_cold_plan="
        f"{(cold.plan_ms * 1e3 + warm_us) / max(warm_us, 1e-9):.2f}x;"
        f"hits={eng.stats['cache_hits']};planner_calls={eng.stats['planner_calls']}",
    )

    small = [
        make_dataset("cora", max_nodes=n // 8, max_feature_dim=cfg.d_model, seed=s)
        for s in range(1, 5)
    ]
    reqs = [GNNRequest(graph=s, features=s.features) for s in small]
    eng.infer_batch(reqs)  # compile + plan the union once
    us_batch = _time(lambda: eng.infer_batch(reqs), reps=3)
    us_seq = _time(lambda: [eng.infer(s, s.features) for s in small], reps=3)
    emit(
        "gnn_serve_batched_union", us_batch,
        f"graphs={len(reqs)};nodes={sum(s.num_nodes for s in small)};"
        f"speedup_vs_sequential={us_seq / max(us_batch, 1e-9):.2f}x",
    )

    # GAT: runtime edge coefficients through the same plan-cached engine —
    # attention changes every request, the structure-keyed plan cache does not.
    gat_cfg = get_config("ample-gat", reduced=True)
    gat = GNNServeEngine(gat_cfg, key=jax.random.PRNGKey(0))
    gat_g = make_dataset("cora", max_nodes=n, max_feature_dim=gat_cfg.d_model, seed=0)
    gat_cold = gat.infer(gat_g, gat_g.features)
    gat_warm = gat.infer(gat_g, gat_g.features)
    gat_us = _time(lambda: gat.infer(gat_g, gat_g.features), reps=3)
    emit(
        "gnn_serve_gat_cold_plan", gat_cold.plan_ms * 1e3,
        f"nodes={gat_g.num_nodes};edges={gat_g.num_edges};"
        f"heads={gat_cfg.gnn_heads};cache_hit={gat_cold.cache_hit}",
    )
    emit(
        "gnn_serve_gat_cache_hit", gat_us,
        f"plan_ms={gat_warm.plan_ms:.3f};cache_hit={gat_warm.cache_hit};"
        f"planner_calls={gat.stats['planner_calls']};"
        f"vs_gcn_warm={gat_us / max(warm_us, 1e-9):.2f}x",
    )


# -------------- runtime-coeff overhead: the scatter cost in isolation
def bench_runtime_coeff(quick: bool) -> None:
    """Static-coeff GCN vs runtime-coeff GCN on the same graph and engine:
    the same values flow, but the runtime path scatters them through the
    ``edge_ids`` indirection per call — the isolated cost of decoupling
    coefficients from compiled plans (outputs are bitwise-identical)."""
    import jax.numpy as jnp

    from repro.core.message_passing import (
        AmpleEngine,
        EngineConfig,
        aggregation_coefficients,
    )
    from repro.graphs.csr import add_self_loops
    from repro.graphs.datasets import make_dataset

    n = 2_000 if quick else 10_000
    g = add_self_loops(make_dataset("pubmed", max_nodes=n, max_feature_dim=128, seed=0))
    x = jnp.asarray(g.features)
    eng = AmpleEngine(g, EngineConfig(mixed_precision=True))
    coeff = jnp.asarray(aggregation_coefficients(g, "gcn"))

    eng.aggregate(x, mode="gcn").block_until_ready()  # jit + plan warm
    eng.aggregate(x, mode="runtime", edge_coeff=coeff).block_until_ready()
    # reps high for a ~ms-scale microbench: the overhead being isolated is a
    # few % of the call, well under run-to-run load noise at 3 reps.
    us_static = _time(
        lambda: eng.aggregate(x, mode="gcn").block_until_ready(), reps=10
    )
    us_rt = _time(
        lambda: eng.aggregate(
            x, mode="runtime", edge_coeff=coeff
        ).block_until_ready(),
        reps=10,
    )
    emit(
        "gnn_runtime_coeff_overhead", us_rt - us_static,
        f"static_us={us_static:.1f};runtime_us={us_rt:.1f};"
        f"overhead={us_rt / max(us_static, 1e-9):.2f}x;edges={g.num_edges}",
    )


def bench_attention(quick: bool) -> None:
    """Per-GAT-layer attention cost at fixed total width (H·dh = 64):
    the retired looped-head baseline (H× softmax/aggregate passes) vs the
    [E, H] head-vectorized jnp path vs the fused-kernel decomposition
    (per-tile (m, l, a) + log-sum-exp combine; jnp oracle timed — the
    Pallas launch itself targets TPU, interpret mode is not a timing).
    Acceptance: vectorized ≥ 2x the looped baseline at H=4. Also times the
    int8 FTE matmul on the load-time repacked weight layout vs unpacked."""
    import jax
    import jax.numpy as jnp

    from repro.core.message_passing import AmpleEngine, EngineConfig
    from repro.graphs.csr import add_self_loops
    from repro.graphs.datasets import make_dataset
    from repro.kernels.segment_agg.ref import attend_tiles_ref

    n = 2_000 if quick else 10_000
    g = add_self_loops(
        make_dataset("pubmed", max_nodes=n, max_feature_dim=64, seed=0)
    )
    eng = AmpleEngine(g, EngineConfig(mixed_precision=False))
    rng = np.random.default_rng(0)
    slope = 0.2

    def looped(scores, z):
        # the pre-PR per-head loop: H separate softmax + aggregate passes
        outs = []
        for h in range(scores.shape[1]):
            sc = jax.nn.leaky_relu(scores[:, h], slope)
            alpha = eng.edge_softmax(sc)
            outs.append(
                eng.aggregate(z[:, h], mode="runtime", edge_coeff=alpha)
            )
        return jnp.stack(outs, axis=1)

    for heads in (2, 4, 8):
        dh = 64 // heads
        z = jnp.asarray(
            rng.standard_normal((g.num_nodes, heads, dh)).astype(np.float32)
        )
        scores = jnp.asarray(
            rng.standard_normal((g.num_edges, heads)).astype(np.float32)
        )
        looped(scores, z).block_until_ready()
        eng.attention_aggregate(scores, z, leaky_slope=slope).block_until_ready()
        us_loop = _time(
            lambda: looped(scores, z).block_until_ready(), reps=5
        )
        us_vec = _time(
            lambda: eng.attention_aggregate(
                scores, z, leaky_slope=slope
            ).block_until_ready(),
            reps=5,
        )
        emit(
            f"gat_attention_h{heads}", us_vec,
            f"looped_us={us_loop:.1f};vectorized_us={us_vec:.1f};"
            f"speedup_vs_looped={us_loop / us_vec:.2f}x;"
            f"edges={g.num_edges};dh={dh}",
        )
        if heads == 4:
            from repro.core.aggregation import tile_edge_coeff

            plans = eng.plans("runtime")
            p = plans["float"]
            dp = eng._device_plans("runtime", plans, edge_ids=True)["float"]
            sc_t = tile_edge_coeff(dp, scores, fill=-jnp.inf)
            fused = jax.jit(
                lambda z, sc_t: attend_tiles_ref(
                    z, dp.gather_idx, sc_t, dp.coeff, dp.seg_ids,
                    dp.out_node, num_nodes=g.num_nodes,
                    segments_per_tile=p.segments_per_tile,
                    leaky_slope=slope,
                )
            )
            fused(z, sc_t).block_until_ready()
            us_fused = _time(
                lambda: fused(z, sc_t).block_until_ready(), reps=5
            )
            emit(
                "gat_attention_fused_oracle_h4", us_fused,
                f"looped_us={us_loop:.1f};"
                f"speedup_vs_looped={us_loop / us_fused:.2f}x;"
                f"tiles={p.num_tiles};one_launch_per_layer=true",
            )

    # int8 FTE: per-call pad/stride vs the load-time repacked tiling
    # (bitwise-identical int32; interpret mode on CPU, layout cost only)
    from repro.kernels.quant_matmul import ops as qm_ops

    m, k, nn = (256, 128, 128) if quick else (1024, 256, 256)
    a_q = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (k, nn)), jnp.int8)
    packed = qm_ops.repack_weight(w_q)
    qm_ops.quant_matmul(a_q, w_q).block_until_ready()
    qm_ops.quant_matmul_repacked(a_q, packed).block_until_ready()
    us_unpacked = _time(
        lambda: qm_ops.quant_matmul(a_q, w_q).block_until_ready(), reps=3
    )
    us_packed = _time(
        lambda: qm_ops.quant_matmul_repacked(a_q, packed).block_until_ready(),
        reps=3,
    )
    emit(
        "fte_int8_repacked_matmul", us_packed,
        f"unpacked_us={us_unpacked:.1f};"
        f"speedup_vs_unpacked={us_unpacked / us_packed:.2f}x;"
        f"m={m};k={k};n={nn};bitwise=true",
    )


# -------------------- gnn-serve continuous: event-driven offered load
def bench_continuous_serve(quick: bool) -> None:
    """Offered-load serving: per-request ``infer`` vs one-shot ``infer_batch``
    vs event-driven continuous batching (AsyncGNNEngine), plus the padded
    size-class plan-cache economics under a varying member mix."""
    import jax

    from repro.configs.base import get_config
    from repro.graphs.datasets import make_dataset
    from repro.serve.async_gnn import AsyncGNNEngine
    from repro.serve.gnn_engine import GNNRequest, GNNServeEngine

    cfg = get_config("ample-gcn", reduced=True)
    base = 120 if quick else 400
    pool = [
        make_dataset("cora", max_nodes=base + 17 * s, max_feature_dim=cfg.d_model, seed=s)
        for s in range(6)
    ]
    eng = GNNServeEngine(
        cfg,
        key=jax.random.PRNGKey(0),
        union_node_bucket=256 if quick else 1024,
        union_edge_bucket=2048 if quick else 8192,
    )
    async_eng = AsyncGNNEngine(eng, window=4)

    # Offered load: 8 outstanding requests drawn from the pool.
    outstanding = [pool[i % len(pool)] for i in range(8)]
    reqs = [GNNRequest(graph=g, features=g.features) for g in outstanding]
    for g in pool:  # warm member plans + jit for every path
        eng.infer(g, g.features)
    async_eng.serve(reqs)
    eng.infer_batch(reqs)

    us_infer = _time(lambda: [eng.infer(g, g.features) for g in outstanding], reps=3)
    us_batch = _time(lambda: eng.infer_batch(reqs), reps=3)
    us_cont = _time(lambda: async_eng.serve(reqs), reps=3)
    n = len(reqs)
    emit(
        "gnn_serve_offered_infer", us_infer / n,
        f"requests={n};throughput_rps={n / (us_infer * 1e-6):.1f};mode=per-request",
    )
    emit(
        "gnn_serve_offered_infer_batch", us_batch / n,
        f"requests={n};throughput_rps={n / (us_batch * 1e-6):.1f};"
        f"speedup_vs_infer={us_infer / max(us_batch, 1e-9):.2f}x;mode=one-union",
    )
    emit(
        "gnn_serve_offered_continuous", us_cont / n,
        f"requests={n};throughput_rps={n / (us_cont * 1e-6):.1f};"
        f"speedup_vs_infer={us_infer / max(us_cont, 1e-9):.2f}x;"
        f"window={async_eng.window};mode=continuous",
    )

    # Varying-mix workload on a fresh engine: padded size classes keep the
    # member-plan cache hot even though no two batches share a composition.
    mix_eng = GNNServeEngine(
        cfg,
        eng.params,
        union_node_bucket=256 if quick else 1024,
        union_edge_bucket=2048 if quick else 8192,
    )
    mix_async = AsyncGNNEngine(mix_eng, window=3)
    rng = np.random.default_rng(0)
    for _ in range(10):
        picks = rng.choice(len(pool), size=rng.integers(2, 4), replace=False)
        for i in picks:
            mix_async.submit(pool[i], pool[i].features)
        mix_async.step()
    mix_async.drain()
    info = mix_async.cache_info()
    lookups = info["member_hits"] + info["member_misses"]
    hit_rate = info["member_hits"] / max(lookups, 1)
    emit(
        "gnn_serve_padded_class_hit_rate", 0.0,
        f"hit_rate={hit_rate:.3f};member_hits={info['member_hits']};"
        f"member_misses={info['member_misses']};"
        f"class_hits={info['class_hits']};class_misses={info['class_misses']};"
        f"planner_calls={info['planner_calls']};batches={info['batches']}",
    )


# --------------------- gnn-serve sharded: partition-aware plan economics
def bench_sharded_serve(quick: bool) -> None:
    """Shard count vs latency, halo-exchange volume and per-shard edge
    balance through the partition-aware GNNServeEngine (host-loop backend —
    the SPMD shard_map backend needs a multi-device mesh)."""
    import jax

    from repro.configs.base import get_config
    from repro.graphs.datasets import make_dataset
    from repro.serve.gnn_engine import GNNServeEngine

    cfg = get_config("ample-gcn", reduced=True)
    n = 2_000 if quick else 10_000
    g = make_dataset("flickr", max_nodes=n, max_feature_dim=cfg.d_model, seed=0)
    base = GNNServeEngine(cfg, key=jax.random.PRNGKey(0))
    base.infer(g, g.features)  # jit warm
    us_1 = _time(lambda: base.infer(g, g.features), reps=3)

    for shards in (2, 4, 8):
        eng = GNNServeEngine(cfg, base.params, num_shards=shards)
        cold = eng.infer(g, g.features)  # pays per-shard planning + jit
        eng.infer(g, g.features)
        us_k = _time(lambda: eng.infer(g, g.features), reps=3)
        rep = eng.shard_report()
        emit(
            f"gnn_serve_sharded_{shards}", us_k,
            f"plan_ms={cold.plan_ms:.1f};vs_unsharded={us_1 / max(us_k, 1e-9):.2f}x;"
            f"edge_balance={rep['edge_balance']:.3f};"
            f"halo_rows_per_layer={rep['halo_total']};"
            f"halo_frac={rep['halo_total'] / max(g.num_nodes, 1):.3f}",
        )

    # ---- partitioner comparison: contiguous edges vs multilevel min-cut.
    # Shuffled planted communities are the adversarial case for contiguous
    # ranges (cluster membership is uncorrelated with node order), and the
    # structure the min-cut partitioner recovers — the halo-volume and
    # overlapped-exchange rows the BENCH_sharded.json baseline gates on.
    import numpy as np

    from repro.graphs.datasets import make_clustered_graph

    n_c = 1_200 if quick else 6_000
    gc = make_clustered_graph(n_c, 8, seed=1, shuffle=True, inter_degree=0.5)
    feats = np.asarray(
        np.random.default_rng(0).standard_normal((n_c, cfg.d_model)), np.float32
    )
    for shards in (2, 4, 8):
        halo_by_kind = {}
        for kind in ("edges", "mincut"):
            eng = GNNServeEngine(
                cfg, base.params, num_shards=shards, partitioner=kind,
                halo_overlap=True,
            )
            eng.infer(gc, feats)  # plan + jit
            us_k = _time(lambda: eng.infer(gc, feats), reps=3)
            r = eng.infer(gc, feats)
            rep = eng.shard_report()
            halo_by_kind[kind] = rep["halo_total"]
            extra = ""
            if kind == "mincut":
                red = 1.0 - rep["halo_total"] / max(halo_by_kind["edges"], 1)
                extra = f";halo_reduction_vs_edges={red:.3f}"
            emit(
                f"gnn_sharded_part_{kind}_{shards}", us_k,
                f"partitioner={kind};edge_balance={rep['edge_balance']:.3f};"
                f"halo_volume={rep['halo_total']};"
                f"halo_frac={rep['halo_total'] / max(gc.num_nodes, 1):.3f};"
                f"halo_bytes={r.halo_bytes};halo_ms={r.halo_ms:.2f};"
                f"halo_overlap={r.halo_overlap:.3f}" + extra,
            )


# ----------------- out-of-core serving: budget vs latency/bytes/hit rate
def _outofcore_row(eng, r, us, in_mem_us):
    s = eng._last_stream
    return (
        f"budget_mb={eng.feature_budget_bytes / (1 << 20):.1f};"
        f"bytes_streamed={r.bytes_streamed};"
        f"chunk_hit_rate={r.chunk_hit_rate:.3f};"
        f"prefetch_overlap={r.prefetch_overlap:.3f};"
        f"stall_ms={r.stall_ms:.1f};copy_ms={r.copy_ms:.1f};"
        f"sparse_rows={s.sparse_rows};evictions={s.evictions};"
        f"vs_inmem={us / max(in_mem_us, 1e-9):.2f}x;streamed={r.streamed}"
    )


def _outofcore_gate(rows) -> None:
    """--quick regression gate: measured overlap must clear 0.3 and the
    chunk hit rate must not regress >5 % (absolute) against the committed
    same-scale baseline (the ``quick_rows`` section of BENCH_prefetch.json).
    ``REPRO_BENCH_NO_GATE=1`` skips — e.g. when refreshing the baseline."""
    import json

    if os.environ.get("REPRO_BENCH_NO_GATE"):
        print("outofcore gate: skipped (REPRO_BENCH_NO_GATE)", flush=True)
        return
    failures = []
    for rec in rows:
        ov = float(rec.get("prefetch_overlap", 0.0))
        if ov < 0.3:
            failures.append(f"{rec['name']}: prefetch_overlap {ov:.3f} < 0.3")
    baseline_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_prefetch.json",
    )
    if os.path.exists(baseline_path):
        with open(baseline_path) as f:
            payload = json.load(f)
        base = {
            r["name"]: r
            for r in payload.get(
                "quick_rows", payload["rows"] if payload.get("quick") else []
            )
        }
        for rec in rows:
            ref = base.get(rec["name"])
            if ref is None or "chunk_hit_rate" not in ref:
                continue
            got, want = float(rec["chunk_hit_rate"]), float(ref["chunk_hit_rate"])
            if got < want - 0.05:
                failures.append(
                    f"{rec['name']}: chunk_hit_rate {got:.3f} regressed >5% "
                    f"vs baseline {want:.3f}"
                )
    else:
        print("outofcore gate: no committed baseline, overlap check only",
              flush=True)
    if failures:
        raise SystemExit(
            "outofcore --quick gate FAILED:\n  " + "\n  ".join(failures)
        )
    print(f"outofcore gate: PASS ({len(rows)} rows)", flush=True)


def bench_outofcore(quick: bool) -> None:
    """Full-scale reddit + yelp inference under feature budgets smaller than
    the feature matrix: the out-of-core path keeps features host-resident and
    streams chunks through the plan-driven prefetcher (async staging worker +
    Belady slot cache + sparse residue). Sweeps budget vs latency, bytes
    streamed, chunk-cache hit rate and the wall-clock stall/copy split, plus
    reorder/pack control arms at the 1/4 point (the artifact rows CI uploads
    as BENCH_prefetch.json). Under --quick the sweep doubles as a regression
    gate against the committed baseline."""
    import dataclasses as dc

    import jax

    from repro.configs.base import get_config
    from repro.graphs.datasets import PAPER_DATASETS, make_dataset
    from repro.serve.gnn_engine import GNNServeEngine

    # --quick: mid-size subsets, CI-friendly; full: the paper's full scales.
    cap = 8_000 if quick else None
    fdim = 128 if quick else None
    tile = 1_024 if quick else 4_096
    gate_rows = []
    for name in ("reddit", "yelp"):
        spec = PAPER_DATASETS[name]
        g = make_dataset(name, max_nodes=cap, max_feature_dim=fdim, seed=0)
        feat_bytes = g.features.nbytes
        base = get_config("ample-gcn", reduced=quick)
        cfg = dc.replace(
            base,
            d_model=g.feature_dim,
            vocab_size=spec.num_classes,
            gnn_edges_per_tile=tile,
        )
        # One engine for the whole sweep: the plan compiles once, and only
        # ``feature_budget_bytes`` (plus the locality knobs for the control
        # arms) moves between points.
        chunk_rows = 1_024 if quick else 8_192
        eng = GNNServeEngine(
            cfg,
            feature_budget_bytes=0,
            feature_chunk_rows=chunk_rows,
            key=jax.random.PRNGKey(0),
        )
        cold = eng.infer(g, g.features)  # planner + dense-path jit, untimed
        # Floor each budget at one f32 chunk (the minimum the cache can hold)
        # rather than a fixed size, so sweep points stay distinct at --quick
        # scales instead of collapsing onto one clamped value.
        floor = chunk_rows * g.feature_dim * 4
        # Untimed streamed warmup: compiles the tile-step/gather/upload jits
        # (budget-independent shapes) so the first sweep point isn't inflated
        # by one-time compilation.
        eng.feature_budget_bytes = max(feat_bytes // 8, floor)
        eng.infer(g, g.features)
        in_mem_us = None
        for frac in (0, 8, 4, 2):  # 0 = in-memory reference, then budget sweep
            eng.feature_budget_bytes = (
                0 if frac == 0 else max(feat_bytes // frac, floor)
            )
            t0 = time.perf_counter()
            r = eng.infer(g, g.features)
            us = (time.perf_counter() - t0) * 1e6
            if frac == 0:
                in_mem_us = us
                emit(
                    f"outofcore_{name}_inmem", us,
                    f"nodes={g.num_nodes};edges={g.num_edges};"
                    f"feat_mb={feat_bytes >> 20};plan_ms={cold.plan_ms:.0f};"
                    f"streamed={r.streamed}",
                )
                continue
            row_name = f"outofcore_{name}_budget_1_{frac}"
            emit(row_name, us, _outofcore_row(eng, r, us, in_mem_us))
            gate_rows.append({
                "name": row_name,
                "prefetch_overlap": f"{r.prefetch_overlap:.3f}",
                "chunk_hit_rate": f"{r.chunk_hit_rate:.3f}",
            })
        # Locality control arms at the 1/4 point: reorder-only is the sweep
        # default above; A/B the plan-order control and the chunk-packed
        # mode through the engine knobs (no hand-built prefetchers).
        eng.feature_budget_bytes = max(feat_bytes // 4, floor)
        for arm, reorder, packing in (
            ("noreorder", False, False),
            ("packed", False, True),
        ):
            eng.stream_reorder, eng.stream_packing = reorder, packing
            eng.infer(g, g.features)  # untimed: packed-plan build + jit warm
            t0 = time.perf_counter()
            r = eng.infer(g, g.features)
            us = (time.perf_counter() - t0) * 1e6
            emit(
                f"outofcore_{name}_arm_{arm}_1_4", us,
                _outofcore_row(eng, r, us, in_mem_us),
            )
        eng.stream_reorder, eng.stream_packing = True, False
    if quick:
        _outofcore_gate(gate_rows)


# ------------- prefetcher calibration: simulated depth vs measured budget
def bench_prefetch_calibration(quick: bool) -> None:
    """Calibrate the discrete-event prefetcher model against the measured
    chunk cache: sweep the simulator's prefetch depth (deeper → fewer stall
    cycles) next to the measured budget sweep (bigger cache → higher chunk
    hit rate); both trends must be monotone (asserted by tests)."""
    from repro.core.scheduler import build_chunk_schedule, build_edge_tile_plan
    from repro.core.simulator import SimConfig, simulate
    from repro.graphs.datasets import make_dataset
    from repro.memory.feature_store import FeatureStore
    from repro.memory.prefetcher import ChunkPrefetcher, StreamStats

    n = 5_000 if quick else 20_000
    g = make_dataset("flickr", max_nodes=n, max_feature_dim=64, seed=0)

    for depth in (0, 1, 2, 4):
        res = simulate(
            g, feature_dim=256, cfg=SimConfig(prefetch_depth=depth)
        )
        emit(
            f"prefetch_sim_depth_{depth}", 0.0,
            f"fetch_stall_frac={res.fetch_stall_frac:.4f};"
            f"latency_ms={res.latency_ms:.3f}",
        )

    store = FeatureStore.from_array(g.features, chunk_rows=512)
    plan = build_edge_tile_plan(g, edges_per_tile=1_024)
    schedule = build_chunk_schedule(plan, store.chunk_rows)
    # Sweep explicit slot counts (budget = slots × chunk bytes): fractional
    # budgets can round to the same slot count at --quick scales, which
    # would record duplicate rows under distinct names.
    for slots in (1, 2, 4, 8):
        budget = slots * store.chunk_bytes_f32
        stats = StreamStats()
        pf = ChunkPrefetcher(
            store, schedule, stream="f32", budget_bytes=budget, stats=stats
        )
        t0 = time.perf_counter()
        pf.aggregate(plan).block_until_ready()
        us = (time.perf_counter() - t0) * 1e6
        emit(
            f"prefetch_measured_slots_{slots}", us,
            f"budget_mb={budget / (1 << 20):.2f};"
            f"chunk_hit_rate={stats.hit_rate:.4f};"
            f"bytes_streamed={stats.bytes_streamed};"
            f"evictions={stats.evictions};waves={stats.waves}",
        )


# --------------------------------------------- MoE event-driven dispatch
# --------------- multi-tenant serving front: priorities, SLOs, fair shares
def bench_tenancy(quick: bool) -> None:
    """Offered-load sweep through the multi-tenant router (serve/tenancy):
    tenant mixes x SLO targets. The rows CI uploads as BENCH_tenancy.json:
    (1) best-effort alone = the capacity baseline; (2) a high-priority gold
    trickle against a saturating best-effort backlog, sweeping gold's SLO
    target — gold's p50/p99 and SLO hit rate, best effort's throughput as a
    fraction of its DWRR fair share (capacity x its unconsumed node
    fraction; the acceptance bar is >= 0.9); (3) a 1:2:4-weighted
    three-tenant backlog — measured node shares vs the weight vector; (4)
    token-bucket admission control under 4x over-rate offered load."""
    import jax

    from repro.configs.base import get_config
    from repro.graphs.datasets import make_dataset
    from repro.serve.async_gnn import AsyncGNNEngine
    from repro.serve.tenancy import RateLimitExceeded, TenantRouter
    from repro.serve.gnn_engine import GNNServeEngine

    cfg = get_config("ample-gcn", reduced=True)
    base = 120 if quick else 400
    pool = [
        make_dataset("cora", max_nodes=base + 13 * s,
                     max_feature_dim=cfg.d_model, seed=s)
        for s in range(5)
    ]
    eng = GNNServeEngine(
        cfg,
        key=jax.random.PRNGKey(0),
        union_node_bucket=256 if quick else 1024,
        union_edge_bucket=2048 if quick else 8192,
    )
    for g in pool:  # warm member plans + jit
        eng.infer(g, g.features)

    window = 4
    n_be = 24 if quick else 80
    n_gold = 6 if quick else 16
    be_reqs = [pool[i % len(pool)] for i in range(n_be)]
    be_nodes = sum(g.num_nodes for g in be_reqs)

    def fresh_router(**tenants):
        r = TenantRouter(AsyncGNNEngine(eng, window=window))
        for name, kw in tenants.items():
            r.add_tenant(name, **kw)
        return r

    # (1) best-effort alone: the capacity baseline (warm run measured).
    fresh_router(be={}).serve([("be", g, g.features) for g in be_reqs])
    r_alone = fresh_router(be={})
    t0 = time.perf_counter()
    r_alone.serve([("be", g, g.features) for g in be_reqs])
    alone_s = time.perf_counter() - t0
    alone_node_tput = be_nodes / alone_s
    emit(
        "tenancy_be_alone", alone_s * 1e6 / n_be,
        f"requests={n_be};throughput_rps={n_be / alone_s:.1f};"
        f"node_throughput={alone_node_tput:.0f};windows={r_alone.stats['windows']};"
        f"mode=baseline",
    )

    # (2) gold trickle vs saturating best-effort backlog, sweeping SLO.
    stride = max(1, (n_be // window) // n_gold)  # gold cadence in windows

    def run_mixed(slo_ms):
        router = fresh_router(
            gold={"priority": 1, "slo_ms": slo_ms},
            be={},
        )
        for g in be_reqs:
            router.submit("be", g, g.features)
        gi = 0
        t0 = time.perf_counter()
        while router.pending or gi < n_gold:
            if gi < n_gold and (
                router.stats["windows"] >= gi * stride or not router.pending
            ):
                g = pool[gi % len(pool)]
                router.submit("gold", g, g.features)
                gi += 1
                continue
            router.step(flush=True)
        return router, time.perf_counter() - t0

    run_mixed(100.0)  # warm this scenario's window compositions (jit + plans)
    for slo_ms in ((100.0,) if quick else (50.0, 100.0, 200.0)):
        router, mixed_s = run_mixed(slo_ms)
        snap = router.snapshot()["tenants"]
        gold, be = snap["gold"], snap["be"]
        gold_frac = gold["completed_nodes"] / (
            gold["completed_nodes"] + be["completed_nodes"]
        )
        be_node_tput = be["completed_nodes"] / mixed_s
        # DWRR fair share: gold is a trickle (never backlogged), so work
        # conservation hands best effort everything gold didn't consume.
        fair_share = alone_node_tput * (1.0 - gold_frac)
        lat = gold["latency_ms"]
        emit(
            f"tenancy_mixed_slo{int(slo_ms)}", mixed_s * 1e6 / (n_be + n_gold),
            f"gold_p50_ms={lat['p50']:.2f};gold_p99_ms={lat['p99']:.2f};"
            f"slo_ms={slo_ms:.0f};slo_hit_rate={gold['slo_hit_rate']:.3f};"
            f"gold_queue_p99_ms={gold['queue_wait_ms']['p99']:.2f};"
            f"be_node_throughput={be_node_tput:.0f};"
            f"be_fair_share_frac={be_node_tput / fair_share:.3f};"
            f"gold_node_frac={gold_frac:.3f};windows={router.stats['windows']};"
            f"mode=priority-slo",
        )

    # (3) weighted contention: three saturating tenants at weights 1:2:4.
    weights = {"w1": 1.0, "w2": 2.0, "w4": 4.0}
    per_tenant = 12 if quick else 32

    def run_weighted():
        router = fresh_router(**{t: {"weight": w} for t, w in weights.items()})
        for t in weights:
            for i in range(per_tenant):
                g = pool[i % len(pool)]
                router.submit(t, g, g.features)
        t0 = time.perf_counter()
        router.drain()
        return router, time.perf_counter() - t0

    run_weighted()  # warm
    router, contended_s = run_weighted()
    snap = router.snapshot()["tenants"]
    # Share over the contended phase: every tenant backlogged from the
    # start, so first-half windows are the weight-driven regime (the tail
    # drains lighter tenants' leftovers work-conservingly).
    first_half = list(router.window_log)[: len(router.window_log) // 2]
    served = {t: 0 for t in weights}
    for w in first_half:
        for tenant, _seq in w:
            served[tenant] += 1
    total_served = max(sum(served.values()), 1)
    wsum = sum(weights.values())
    shares = ";".join(
        f"{t}_share={served[t] / total_served:.3f}"
        f"(want={weights[t] / wsum:.3f})"
        for t in weights
    )
    max_err = max(
        abs(served[t] / total_served - weights[t] / wsum) for t in weights
    )
    emit(
        "tenancy_weighted_shares", contended_s * 1e6 / (3 * per_tenant),
        f"{shares};max_share_error={max_err:.3f};"
        f"windows={router.stats['windows']};mode=dwrr-weights",
    )

    # (4) admission control: 4x over-rate offered load hits the bucket.
    router = fresh_router(limited={"rate_rps": 200.0, "burst": float(n_be // 4)})
    admitted = rejected = 0
    for g in be_reqs:  # burst-dominated: bucket drains mid-stream
        try:
            router.submit("limited", g, g.features)
            admitted += 1
        except RateLimitExceeded:
            rejected += 1
    router.drain()
    emit(
        "tenancy_rate_limit", 0.0,
        f"offered={n_be};admitted={admitted};rejected={rejected};"
        f"rejected_telemetry={router.snapshot()['tenants']['limited']['rejected']};"
        f"mode=token-bucket",
    )


def bench_moe_dispatch(quick: bool) -> None:
    import jax
    import jax.numpy as jnp

    from repro.models.lm.moe import moe_apply, moe_init, _expert_ffn

    d, f, e, k = 128, 256, 16, 2
    t = 2_048 if quick else 8_192
    params = moe_init(jax.random.PRNGKey(0), d, f, e, "swiglu", dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, t, d))

    sorted_fn = jax.jit(
        lambda p, x: moe_apply(p, x, num_experts=e, top_k=k, kind="swiglu")[0]
    )
    us = _time(lambda: sorted_fn(params, x).block_until_ready())

    def dense(p, x):  # every expert processes every token (no dispatch)
        xf = jnp.broadcast_to(x.reshape(1, 1, t, d), (1, e, t, d))
        probs = jax.nn.softmax(x.reshape(t, d) @ p["router"], -1)
        y = _expert_ffn(p["experts"], xf, "swiglu")[0]
        return jnp.einsum("etd,te->td", y, probs)

    dense_fn = jax.jit(dense)
    us_dense = _time(lambda: dense_fn(params, x).block_until_ready(), reps=1)
    emit("moe_event_driven_dispatch", us,
         f"speedup_vs_dense_all_experts={us_dense/us:.2f}x;capacity_factor=1.25")


# --------------------------------------------------- kernel sanity timings
def bench_kernels(quick: bool) -> None:
    """Pallas kernels run in interpret mode on CPU — correctness surrogates;
    real perf is the TPU target. The oracle (jnp) path time is reported."""
    import jax.numpy as jnp

    from repro.core import build_edge_tile_plan
    from repro.graphs.datasets import make_lognormal_graph
    from repro.kernels.segment_agg.ref import aggregate_tiles_ref

    g = make_lognormal_graph(1_000, 5.0, seed=0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1_000, 128)).astype(np.float32))
    plan = build_edge_tile_plan(g, edges_per_tile=128)
    args = (
        jnp.asarray(plan.gather_idx), jnp.asarray(plan.coeff),
        jnp.asarray(plan.seg_ids), jnp.asarray(plan.out_node),
    )
    kw = dict(num_nodes=1_000, segments_per_tile=plan.segments_per_tile)
    us = _time(lambda: aggregate_tiles_ref(x, *args, **kw).block_until_ready())
    emit("kernel_segment_agg_oracle", us,
         f"tiles={plan.num_tiles};occupancy={plan.lane_occupancy:.3f}")

    # fused segment-softmax (attention) kernel oracle: one tile scan does
    # LeakyReLU → segment-max → exp → segment-sum → weighted aggregate
    from repro.core.aggregation import tile_edge_coeff, to_device_plan
    from repro.kernels.segment_agg.ref import attend_tiles_ref

    h, dh = 4, 32
    z = jnp.asarray(rng.standard_normal((1_000, h, dh)).astype(np.float32))
    scores = jnp.asarray(
        rng.standard_normal((g.num_edges, h)).astype(np.float32)
    )
    dp = to_device_plan(plan, with_edge_ids=True)
    sc_t = tile_edge_coeff(dp, scores, fill=-jnp.inf)
    us = _time(
        lambda: attend_tiles_ref(
            z, dp.gather_idx, sc_t, dp.coeff, dp.seg_ids, dp.out_node,
            num_nodes=1_000, segments_per_tile=plan.segments_per_tile,
            leaky_slope=0.2,
        ).block_until_ready()
    )
    emit("kernel_segment_softmax_oracle", us,
         f"tiles={plan.num_tiles};heads={h};dh={dh};fused=true")


BENCHES = [
    table4_dq_ratios,
    table5_latency,
    figure4_speedup,
    bench_engine_paths,
    bench_mixed_precision,
    bench_gnn_serve,
    bench_runtime_coeff,
    bench_attention,
    bench_continuous_serve,
    bench_sharded_serve,
    bench_outofcore,
    bench_prefetch_calibration,
    bench_tenancy,
    bench_moe_dispatch,
    bench_kernels,
]


def write_artifact(path: str, quick: bool) -> None:
    """Persist the emitted rows as a JSON artifact (CI uploads this — the
    bench trajectory across PRs lives in these files, not the logs)."""
    import json
    import platform

    records = []
    for row in ROWS:
        name, us, derived = row.split(",", 2)
        rec = {"name": name, "us_per_call": float(us)}
        for part in derived.split(";"):
            if "=" in part:
                k, v = part.split("=", 1)
                rec[k] = v
        records.append(rec)
    payload = {
        "quick": quick,
        "python": platform.python_version(),
        "machine": platform.machine(),
        "rows": records,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {len(records)} rows to {path}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated substrings of bench names to run")
    ap.add_argument("--skip", default=None,
                    help="comma-separated substrings of bench names to skip")
    ap.add_argument("--out", default=None,
                    help="write rows as a JSON artifact (e.g. BENCH_serve.json)")
    ap.add_argument("--trace-out", default=None,
                    help="record request-lifecycle spans during the benches "
                         "and write Chrome-trace-event JSON here (open in "
                         "Perfetto); empty = tracing off")
    args = ap.parse_args()
    wanted = [s for s in (args.only or "").split(",") if s]
    unwanted = [s for s in (args.skip or "").split(",") if s]
    if args.trace_out:
        from repro.observe import trace as otrace

        otrace.enable()
    print("name,us_per_call,derived")
    for bench in BENCHES:
        if wanted and not any(s in bench.__name__ for s in wanted):
            continue
        if any(s in bench.__name__ for s in unwanted):
            continue
        bench(args.quick)
    if args.trace_out:
        rec = otrace.get_recorder()
        rec.export(args.trace_out)
        print(
            f"trace: {len(rec.spans())} spans -> {args.trace_out} "
            f"(dropped={rec.dropped})"
        )
    if args.out:
        write_artifact(args.out, args.quick)


if __name__ == "__main__":
    main()
