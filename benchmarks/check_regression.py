"""Bench regression check: fresh ``--quick`` artifacts vs committed baselines.

``benchmarks/run.py --out`` persists each bench family as a JSON artifact;
the committed ``BENCH_*.json`` files at the repo root are the accepted
baselines. This script diffs a fresh artifact against its baseline row by
row and reports findings at two severities:

* **WARN** (default for everything): ``us_per_call`` slowdowns beyond the
  tolerance, quality-metric drift (hit rates, overlap, SLO hit rates).
  Wall-clock on shared CI runners is noisy, so timing regressions never
  fail the build — they leave a visible trail in the log instead.
* **FAIL** (hard, reused from the out-of-core ``--quick`` gate in
  ``run.py``): measured ``prefetch_overlap`` below 0.3, or ``chunk_hit_rate``
  regressing more than 5 % absolute against the same-scale baseline. These
  are scale-free scheduling-quality metrics, not wall-clock, so they are
  stable enough to gate on. ``REPRO_BENCH_NO_GATE=1`` demotes them to
  WARN — e.g. while refreshing a baseline.

Baselines with a ``quick_rows`` section (BENCH_prefetch.json) are compared
at quick scale; otherwise the artifact's ``rows`` are used and, when the
fresh and baseline scales differ (fresh ``--quick`` vs a committed
full-scale run), wall-clock comparison is skipped and only scale-free
metrics are diffed.

Usage (CI writes fresh artifacts to a scratch dir so the committed
baselines stay intact)::

    python -m benchmarks.run --quick --out bench_fresh/BENCH_prefetch.json \
        --only outofcore,prefetch_calibration
    python benchmarks/check_regression.py --fresh bench_fresh/BENCH_prefetch.json

Exit status is 1 iff any FAIL finding survives.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, NamedTuple, Optional, Tuple

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Hard gate thresholds — keep in lockstep with run.py::_outofcore_gate.
MIN_PREFETCH_OVERLAP = 0.3
MAX_HIT_RATE_DROP = 0.05

# Warn-only thresholds.
SLOWDOWN_TOLERANCE = 1.5  # fresh us_per_call > 1.5x baseline -> WARN
# Scale-free quality metrics: (field, max absolute drop before WARN).
QUALITY_FIELDS: Tuple[Tuple[str, float], ...] = (
    ("chunk_hit_rate", 0.01),
    ("prefetch_overlap", 0.10),
    ("slo_hit_rate", 0.05),
    ("halo_overlap", 0.15),
    ("halo_reduction_vs_edges", 0.10),
)
# Scale-free quality metrics where HIGHER is worse: (field, max absolute
# rise before WARN). halo_frac is halo rows per owned node — a partitioner
# change that inflates the exchange volume shows up here.
INVERTED_QUALITY_FIELDS: Tuple[Tuple[str, float], ...] = (
    ("halo_frac", 0.10),
)


class Finding(NamedTuple):
    severity: str  # "FAIL" | "WARN"
    row: str
    message: str

    def __str__(self) -> str:
        return f"[{self.severity}] {self.row}: {self.message}"


def _to_float(v) -> Optional[float]:
    try:
        return float(v)
    except (TypeError, ValueError):
        return None


def load_rows(path: str) -> Tuple[Dict[str, dict], bool]:
    """Load an artifact; returns (name -> record, is_quick_scale).

    Prefers the ``quick_rows`` section when present (the same-scale baseline
    the quick gate compares against), else falls back to ``rows``.
    """
    with open(path) as f:
        payload = json.load(f)
    if payload.get("quick_rows"):
        return {r["name"]: r for r in payload["quick_rows"]}, True
    return {r["name"]: r for r in payload.get("rows", [])}, bool(
        payload.get("quick")
    )


def check_hard_gates(fresh: Dict[str, dict], base: Dict[str, dict]) -> List[Finding]:
    """The PR-8 out-of-core gate criteria, applied artifact-wide."""
    out: List[Finding] = []
    for name, rec in sorted(fresh.items()):
        ov = _to_float(rec.get("prefetch_overlap"))
        if ov is not None and ov < MIN_PREFETCH_OVERLAP:
            out.append(Finding(
                "FAIL", name,
                f"prefetch_overlap {ov:.3f} < {MIN_PREFETCH_OVERLAP}",
            ))
        hit = _to_float(rec.get("chunk_hit_rate"))
        ref = _to_float(base.get(name, {}).get("chunk_hit_rate"))
        if hit is not None and ref is not None and hit < ref - MAX_HIT_RATE_DROP:
            out.append(Finding(
                "FAIL", name,
                f"chunk_hit_rate {hit:.3f} regressed >"
                f"{MAX_HIT_RATE_DROP:.0%} vs baseline {ref:.3f}",
            ))
    return out


def check_soft_drift(
    fresh: Dict[str, dict],
    base: Dict[str, dict],
    *,
    same_scale: bool,
    slowdown: float = SLOWDOWN_TOLERANCE,
) -> List[Finding]:
    """Warn-only comparisons: wall-clock slowdowns and quality drift."""
    out: List[Finding] = []
    for name, rec in sorted(fresh.items()):
        ref = base.get(name)
        if ref is None:
            out.append(Finding("WARN", name, "no baseline row (new bench?)"))
            continue
        if same_scale:
            us, us_ref = _to_float(rec.get("us_per_call")), _to_float(
                ref.get("us_per_call")
            )
            if us and us_ref and us > us_ref * slowdown:
                out.append(Finding(
                    "WARN", name,
                    f"us_per_call {us:.0f} is {us / us_ref:.2f}x baseline "
                    f"{us_ref:.0f} (tolerance {slowdown:.2f}x)",
                ))
        for field, max_drop in QUALITY_FIELDS:
            got, want = _to_float(rec.get(field)), _to_float(ref.get(field))
            if got is not None and want is not None and got < want - max_drop:
                out.append(Finding(
                    "WARN", name,
                    f"{field} {got:.3f} drifted below baseline "
                    f"{want:.3f} (tolerance {max_drop})",
                ))
        for field, max_rise in INVERTED_QUALITY_FIELDS:
            got, want = _to_float(rec.get(field)), _to_float(ref.get(field))
            if got is not None and want is not None and got > want + max_rise:
                out.append(Finding(
                    "WARN", name,
                    f"{field} {got:.3f} drifted above baseline "
                    f"{want:.3f} (tolerance {max_rise})",
                ))
    for name in sorted(set(base) - set(fresh)):
        out.append(Finding("WARN", name, "baseline row missing from fresh run"))
    return out


def check_artifact(
    fresh_path: str, baseline_path: Optional[str] = None
) -> List[Finding]:
    """All findings for one fresh artifact vs its committed baseline."""
    if baseline_path is None:
        baseline_path = os.path.join(REPO_ROOT, os.path.basename(fresh_path))
    fresh, fresh_quick = load_rows(fresh_path)
    have_baseline = os.path.exists(baseline_path) and not os.path.samefile(
        fresh_path, baseline_path
    )
    if not have_baseline:
        # No committed baseline (or comparing a file to itself): hard gates
        # still apply — they don't need a baseline for the overlap floor.
        base: Dict[str, dict] = {}
        base_quick = fresh_quick
        note = "no baseline"
    else:
        base, base_quick = load_rows(baseline_path)
        note = os.path.relpath(baseline_path, REPO_ROOT)
    findings = check_hard_gates(fresh, base)
    if base:
        findings += check_soft_drift(
            fresh, base, same_scale=(fresh_quick == base_quick)
        )
    print(
        f"{os.path.basename(fresh_path)}: {len(fresh)} rows vs {note} "
        f"({len(base)} rows)"
        + ("" if fresh_quick == base_quick else " [scale mismatch: "
           "wall-clock comparison skipped]"),
        flush=True,
    )
    return findings


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", nargs="+", required=True,
                    help="fresh artifact JSON path(s) from run.py --out")
    ap.add_argument("--baseline", default=None,
                    help="explicit baseline path (single --fresh only); "
                         "default: same basename at the repo root")
    args = ap.parse_args(argv)
    if args.baseline and len(args.fresh) != 1:
        ap.error("--baseline requires exactly one --fresh artifact")

    all_findings: List[Finding] = []
    for path in args.fresh:
        all_findings += check_artifact(path, args.baseline)

    no_gate = bool(os.environ.get("REPRO_BENCH_NO_GATE"))
    if no_gate:
        all_findings = [
            Finding("WARN", f.row, f.message + " [gate disabled]")
            if f.severity == "FAIL" else f
            for f in all_findings
        ]
    for f in all_findings:
        print(str(f), flush=True)
    fails = [f for f in all_findings if f.severity == "FAIL"]
    warns = [f for f in all_findings if f.severity == "WARN"]
    print(
        f"check_regression: {len(fails)} FAIL, {len(warns)} WARN"
        + (" (REPRO_BENCH_NO_GATE)" if no_gate else ""),
        flush=True,
    )
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
